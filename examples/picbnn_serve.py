"""PiC-BNN LM head serving demo (deliverable b).

Serves musicgen-medium (reduced) through the decode path TWICE over the
same binary CAM match:
  1. "exact" readout — full-precision POPCOUNT per class (what an
     ADC/TDC-based processing-in-memory design reads out; the paper's
     competitor baseline),
  2. "votes" readout — PiC-BNN Algorithm 1: purely binary measurements
     across the threshold sweep, majority ranking, no ADC.

Reports the greedy-decode agreement between the two readouts — the
LM-scale version of the paper's "binary votes recover the argmax" claim —
plus the HBM-traffic saving of the bit-packed head.

Run:  PYTHONPATH=src python examples/picbnn_serve.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as M


def main():
    rng = np.random.default_rng(0)
    cfg_votes = configs.get_config("musicgen-medium+smoke+cam-head")
    cfg_exact = configs.get_config("musicgen-medium+smoke+cam-head-exact")

    # identical weights for both readouts (same init key/structure)
    params = M.init_params(cfg_votes, jax.random.PRNGKey(0))

    b, s, steps = 4, 12, 16
    embeds = jnp.asarray(
        rng.normal(0, 1, (b, s, cfg_votes.d_model)).astype(np.float32)
    )
    frames = [
        jnp.asarray(
            np.random.default_rng(100 + t)
            .normal(0, 1, (b, 1, cfg_votes.d_model))
            .astype(np.float32)
        )
        for t in range(steps - 1)
    ]

    streams = {}
    for name, cfg in [("adc-exact-readout", cfg_exact),
                      ("picbnn-votes", cfg_votes)]:
        logits, cache = M.prefill(params, cfg, embeds=embeds,
                                  max_len=s + steps)
        toks = [np.argmax(np.asarray(logits), -1)]
        for t, nxt in enumerate(frames):
            lg, cache = M.decode(params, cfg, cache, nxt, jnp.int32(s + t))
            toks.append(np.argmax(np.asarray(lg), -1))
        streams[name] = np.stack(toks, 1)  # [B, steps]
        print(f"[{name}] first stream: {streams[name][0][:10].tolist()}")

    agree = (streams["adc-exact-readout"] == streams["picbnn-votes"]).mean()
    print(f"\ngreedy-decode agreement, ADC readout vs PiC-BNN votes: "
          f"{agree:.3f}")
    print("(every disagreement is a vote tie from the threshold-sweep "
          "quantization — the paper's precision/efficiency trade)")

    # Fig. 5 at LM scale: agreement grows with the pass count, exactly as
    # the paper's accuracy grows with output-layer executions — but at
    # 2048 classes the required pass count is larger than the paper's 33.
    import dataclasses
    from repro.models import binary_lm

    print("\npass-count sweep (Fig. 5 analogue, 2048-way codebook):")
    rng3 = np.random.default_rng(5)
    h = jnp.asarray(rng3.normal(0, 1, (256, cfg_votes.d_model))
                    .astype(np.float32))
    for n_pass in (9, 17, 33, 65, 129):
        c = dataclasses.replace(cfg_votes, cam_head_thresholds=n_pass)
        ph = binary_lm.init_cam_head(c, jax.random.PRNGKey(0))
        votes = np.asarray(binary_lm.cam_head_logits(ph, c, h))
        exact = np.asarray(binary_lm.cam_head_logits(
            ph, dataclasses.replace(c, cam_head_mode="exact"), h))
        a = (votes.argmax(-1) == exact.argmax(-1)).mean()
        print(f"  {n_pass:4d} passes: argmax agreement {a:.3f}")

    d, v = cfg_votes.d_model, cfg_votes.vocab_size
    dense_bytes = d * v * 2  # bf16 head read per token
    cam_bytes = d * v // 8  # bit-packed rows
    print(f"\nLM-head HBM traffic per decoded token: dense bf16 "
          f"{dense_bytes/1e6:.2f} MB vs packed CAM {cam_bytes/1e6:.3f} MB "
          f"({dense_bytes//cam_bytes}x less); prefill logits also skip "
          f"the vocab matmul's f32 accumulation")


if __name__ == "__main__":
    main()

"""Quickstart: the paper's full pipeline in one script.

1. Generate a synthetic MNIST-like dataset (10 classes, 28x28).
2. Train the paper's binary MLP (784 -> 128 -> 10) with sign-STE + BN.
3. Fold batch-norm into integer constants C_j (Eq. 3).
4. Deploy to CAM arrays (bank tiling) and run Algorithm 1: 33 output-layer
   executions with swept HD tolerance, majority vote.
5. Report: software baseline vs end-to-end-binary accuracy, and the
   silicon performance model (Table II figures).

Run:  PYTHONPATH=src python examples/quickstart.py [--fast]
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.core import bnn, ensemble, mapping
from repro.core.device_model import SILICON, knob_schedule
from repro.data.synthetic import MNIST_LIKE, binarize_images, make_dataset
from repro.deploy import Deployment, deploy
from repro.spec import InferenceSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    epochs = 3 if args.fast else 10
    n_train = 2000 if args.fast else 8000

    print("=== 1. synthetic MNIST-like dataset ===")
    tx, ty, vx, vy = make_dataset(MNIST_LIKE, n_train=n_train, n_test=1000)
    txb, vxb = binarize_images(tx), binarize_images(vx)
    print(f"train {txb.shape}, test {vxb.shape}, inputs binarized to +-1")

    print("=== 2. train binary MLP 784->128->10 (sign-STE + BN) ===")
    cfg = bnn.MLPConfig(layer_sizes=(784, 128, 10), bias_cells=64)
    t0 = time.time()
    params = bnn.train_mlp(
        jax.random.PRNGKey(0), cfg, txb, ty, epochs=epochs, batch=128,
        lr=2e-3, verbose=True,
    )
    print(f"trained in {time.time() - t0:.1f}s")
    sw = bnn.eval_accuracy(params, cfg, vxb, vy, topk=(1, 2))
    print(f"software baseline: top1={sw['top1']:.4f} top2={sw['top2']:.4f}")

    print("=== 3. fold BN into C_j (Eq. 3) ===")
    folded = bnn.fold(params, cfg)
    for i, f in enumerate(folded):
        print(f"layer {i}: W{f.weights_pm1.shape}, C_j in "
              f"[{f.c.min()}, {f.c.max()}]")

    print("=== 4. map to CAM banks ===")
    mapped = [mapping.map_layer(l, cfg.bias_cells) for l in folded[:-1]]
    for i, m in enumerate(mapped):
        print(f"layer {i}: plan {m.plan}")
    ecfg = ensemble.EnsembleConfig()
    head = ensemble.build_head(folded[-1], ecfg)
    knobs, achieved = knob_schedule(len(ecfg.thresholds), 64)
    print(f"output head: {head.n_classes} class rows, "
          f"{len(ecfg.thresholds)} passes; first knob settings "
          f"(V_ref,V_eval,V_st)={knobs[0].round(3).tolist()} -> HD "
          f"{achieved[0]:.1f}")

    print("=== 5. Algorithm 1 inference (deployment + InferenceSpec) ===")
    # deployment artifact: folded layers + ensemble config bundled; the
    # fused packed-domain pipeline (all layers + the 33-threshold vote in
    # one compiled program) compiles lazily per request spec
    dep = deploy(folded, config=cfg, ens_cfg=ecfg)
    pipe = dep.pipeline()
    t0 = time.time()
    pred = dep.run(jnp.asarray(vxb), InferenceSpec(reduction="argmax"))
    acc = float((pred == jnp.asarray(vy)).mean())
    dt = time.time() - t0
    print(f"  end-to-end-binary top1 [fused pipeline/{pipe.impl}]: "
          f"{acc:.4f}  ({len(vy) / dt / 1e3:.1f}K inf/s incl. compile)")
    # silicon PVT noise: the SAME fused program family, device physics
    # threaded through — a spec field selects the draw, the LLN claim is
    # 33 noisy passes ~ noiseless accuracy
    dep_si = deploy(folded, config=cfg, ens_cfg=ecfg, noise=SILICON)
    pred_si = dep_si.run(
        jnp.asarray(vxb),
        InferenceSpec(noise="batch", reduction="argmax"),
        key=jax.random.PRNGKey(7),
    )
    acc_si = float((pred_si == jnp.asarray(vy)).mean())
    print(f"  end-to-end-binary top1 [silicon PVT noise, fused]: "
          f"{acc_si:.4f}  (delta vs noiseless {100 * (acc - acc_si):+.2f} "
          f"points — LLN over {ecfg.n_passes} passes)")

    print("=== 6. silicon performance model (Table II) ===")
    plans = [m.plan for m in mapped] + [
        mapping.plan_layer(10, 128, cfg.bias_cells)
    ]
    cost = mapping.model_inference_cost(plans, len(ecfg.thresholds))
    print(f"  {cost.cycles} cycles/inference @25MHz -> "
          f"{cost.inferences_per_s/1e3:.0f}K inf/s "
          f"(paper: 560K); {1.0/cost.energy_j/1e6:.0f}M inf/s/W "
          f"(paper: 703M)")

    print("=== 7. serving: register deployments, even from disk ===")
    # both deployments behind one submit() API; silicon requests carry a
    # per-request PRNG key, so served draws are reproducible bit-for-bit.
    # The noiseless model round-trips through Deployment.save/load — the
    # path a production server takes when registering models from a
    # checkpoint directory.
    from repro.serve.picbnn import BatchingPolicy, PicBnnServer

    srv = PicBnnServer(BatchingPolicy(max_batch=256, max_wait_us=500.0))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        dep.save(ckpt_dir)  # manifest + bit-packed weights
        srv.register("mnist", Deployment.load(ckpt_dir))
        srv.register("mnist-si", dep_si)
        srv.warmup()  # precompile every bucket: no first-request spike
        with srv:
            handles = [srv.submit("mnist", vxb[i]) for i in range(512)]
            h_si = srv.submit("mnist-si", vxb[0],
                              key=jax.random.PRNGKey(7))
            served = [h.wait() for h in handles]
            print(f"  served pred[0]={served[0]} (direct: {int(pred[0])}"
                  f"), silicon pred[0]={h_si.wait()}")
    print("  " + srv.stats().summary().replace("\n", "\n  "))

    print("=== 8. end-to-end-binary CNN workload ===")
    # the input layer is binary too: raw [0,1] pixels pass through a
    # thermometer encoding INSIDE the compiled program (the paper's
    # end-to-end claim, conv edition — see DESIGN.md §10)
    from repro.configs.paper_cnn import MNIST_CNN, deploy_cnn
    from repro.core import convnet

    cnn_epochs = 2 if args.fast else 6
    cnn_params = convnet.train_cnn(
        jax.random.PRNGKey(1), MNIST_CNN, tx, ty, epochs=cnn_epochs
    )
    # trained params + config in, deployment out (the fold runs inside)
    cnn_dep = deploy_cnn(MNIST_CNN, cnn_params)
    acc_sw = convnet.eval_cnn_accuracy(cnn_params, MNIST_CNN, vx, vy)["top1"]
    acc_cnn = float((cnn_dep.run(jnp.asarray(vx),
                                 InferenceSpec(reduction="argmax"))
                     == jnp.asarray(vy)).mean())
    si = convnet.cnn_inference_cost(MNIST_CNN).inferences_per_s
    print(f"  conv(3x3x32,s2) x2 -> FC128 -> 10-row CAM head, "
          f"thermometer-8 input")
    print(f"  software top1 {acc_sw:.4f} vs deployed Algorithm-1 "
          f"{acc_cnn:.4f}; silicon equivalent {si/1e3:.1f}K inf/s")
    cnn_srv = PicBnnServer(BatchingPolicy(max_batch=128, max_wait_us=500.0))
    cnn_srv.register("cnn-mnist", cnn_dep,
                     silicon_cost=convnet.cnn_inference_cost(MNIST_CNN))
    with cnn_srv:
        h = cnn_srv.submit("cnn-mnist", vx[0])  # raw [0,1] pixels
        direct = int(cnn_dep.run(vx[:1],
                                 InferenceSpec(reduction="argmax"))[0])
        print(f"  served CNN pred[0]={h.wait()} (direct: {direct})")


if __name__ == "__main__":
    main()

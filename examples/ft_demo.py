"""Fault-tolerance demo (deliverable b, bonus example).

Trains a small LM under the supervisor while injecting two simulated node
failures and one straggler episode; shows checkpoint/restart recovery,
straggler detection, and that the final loss trajectory matches a
failure-free run (deterministic replay).

Run:  PYTHONPATH=src python examples/ft_demo.py
"""

import shutil
import tempfile
from pathlib import Path

import jax
import numpy as np

from repro import configs
from repro.data.tokens import DataConfig, synthetic_stream
from repro.ft import Supervisor, SupervisorConfig, failing_step, slow_step
from repro.train import TrainConfig, init_train_state
from repro.train.train_step import train_step
import functools


def main():
    cfg = configs.get_config("llama3.2-1b+smoke")
    tcfg = TrainConfig()
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
    )
    step = jax.jit(functools.partial(train_step, cfg, tcfg))

    def make_data(start):
        dcfg = DataConfig(batch=4, seq_len=32, vocab_size=cfg.vocab_size)
        it = synthetic_stream(dcfg)
        for _ in range(start):
            next(it)
        return it

    def run(step_fn, tag):
        d = Path(tempfile.mkdtemp(prefix=f"ftdemo_{tag}_"))
        alerts = []
        sup = Supervisor(
            SupervisorConfig(ckpt_dir=d, ckpt_every=10, backoff_s=0.0,
                             straggler_z=3.0, straggler_patience=2),
            step_fn, make_data, template,
            on_straggler=lambda a: alerts.append(a),
        )
        final = sup.run(state, 40)
        losses = [h["loss"] for h in sup.history]
        shutil.rmtree(d, ignore_errors=True)
        return final, losses, sup.restarts, alerts

    print("=== failure-free reference run (40 steps) ===")
    clean_final, clean_losses, _, _ = run(step, "clean")
    print(f"final loss {clean_losses[-1]:.4f}")

    print("\n=== faulted run: failures @ step 13 & 27, straggler @ 31-35 ===")
    flaky = failing_step(step, fail_at=[13, 27])
    flaky = slow_step(flaky, slow_at=range(31, 36), delay_s=0.8)
    fault_final, fault_losses, restarts, alerts = run(flaky, "flaky")
    print(f"final loss {fault_losses[-1]:.4f}  restarts={restarts}  "
          f"straggler alerts={len(alerts)}")
    for a in alerts[:2]:
        print(f"  alert: step {a['step']} took {a['dt']:.2f}s "
              f"(mean {a['mean']:.2f}s, z={a['z']:.1f})")

    w_clean = np.asarray(
        jax.tree_util.tree_leaves(clean_final["params"])[0]
    )
    w_fault = np.asarray(
        jax.tree_util.tree_leaves(fault_final["params"])[0]
    )
    same = np.allclose(w_clean, w_fault, atol=1e-5)
    print(f"\nfinal params identical to failure-free run: {same} "
          f"(checkpoint/restart + deterministic replay)")


if __name__ == "__main__":
    main()

"""End-to-end LM training driver (deliverable b).

Trains a ~100M-parameter llama-style model for a few hundred steps on
synthetic token data through the full production stack: config -> mesh ->
sharding rules -> AdamW train step -> fault-tolerant supervisor with
async checkpointing.

CPU-friendly default is a scaled-down preset; pass --preset 100m for the
full 100M x 300-step run (hours on this single-core container, minutes
on accelerators — same code path).

Run:  PYTHONPATH=src python examples/lm_train.py [--preset tiny|100m]
"""

import argparse
import sys

from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_train")
    args = ap.parse_args()

    if args.preset == "100m":
        argv = [
            "--arch", "custom-100m", "--steps", "300", "--batch", "8",
            "--seq", "512", "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "50", "--log-every", "10",
        ]
    else:
        argv = [
            "--arch", "llama3.2-1b+smoke", "--steps", "60", "--batch", "8",
            "--seq", "64", "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "20", "--log-every", "10", "--lr", "1e-2",
        ]
    losses = T.main(argv)
    assert len(losses) >= 60 or args.preset == "100m"


if __name__ == "__main__":
    main()

"""End-to-end inference throughput: fused packed-domain pipeline vs the
layer-by-layer unpacked baseline.

The paper's headline number is throughput (560 K inf/s) from an
end-to-end binary flow where activations never leave the array.  This
benchmark measures the TPU-translation analogue on the deployed
paper MLP (784-128-10, 33 output passes):

  baseline — the pre-pipeline deployed path: per layer, pack the ±1
             float activations (shift-broadcast pack), broadcast-XOR
             popcount matvec, +C, sign back to ±1 floats — i.e.
             activations round-trip through the unpacked domain between
             every layer — then the fused head vote.  Ops dispatch
             eagerly, exactly as `mapping.layer_forward` + `votes_fused`
             executed before the fused pipeline existed.
  fused    — `pipeline.compile_pipeline`: one compiled program, packed
             uint32 activations end to end.

Both paths are verified vote-identical before timing.  Results are
emitted as `BENCH_e2e.json` at the repo root (schema picbnn-bench-e2e/v1)
so the perf trajectory is machine-readable across PRs.

Run:  PYTHONPATH=src python -m benchmarks.e2e_throughput [--fast]
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize, bnn, ensemble
from repro.deploy import deploy
from repro.spec import VOTES  # the one spec this benchmark times

REPO_ROOT = Path(__file__).resolve().parents[1]

PAPER_SIZES = (784, 128, 10)


def random_folded(sizes, seed=0, cmax=40, bias_cells=64):
    """A random deployed net with fold-style parity-adjusted C_j."""
    rng = np.random.default_rng(seed)
    layers = []
    for i in range(len(sizes) - 1):
        n_in, n_out = sizes[i], sizes[i + 1]
        c = bnn.parity_adjust_c(
            rng.integers(-cmax, cmax + 1, n_out), n_in, bias_cells
        )
        layers.append(bnn.FoldedLayer(
            weights_pm1=rng.choice([-1, 1], (n_out, n_in)).astype(np.int8),
            c=c,
        ))
    return layers


def make_baseline(folded, head):
    """The pre-pipeline layer-by-layer unpacked deployed path (eager)."""
    w_packed = [
        binarize.pack_bits(jnp.asarray((l.weights_pm1 > 0).astype(np.uint8)))
        for l in folded[:-1]
    ]
    cs = [jnp.asarray(l.c, jnp.int32) for l in folded[:-1]]
    n_bits = [l.n_in for l in folded[:-1]]

    def baseline(x_pm1):
        h = x_pm1
        for wp, c, nb in zip(w_packed, cs, n_bits):
            # activations leave the binary domain every layer:
            # float -> bits -> packed -> int dot -> float sign
            xp = binarize.pack_bits_reference(binarize.to_bits(h))
            hd = binarize.hamming_packed(xp[:, None, :], wp)
            y = (nb - 2 * hd) + c[None, :]
            h = jnp.where(y >= 0, 1.0, -1.0)
        return ensemble.votes_fused(head, h)

    return baseline


def _time(fn, x, reps):
    jax.block_until_ready(fn(x))  # warm / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(x))
    return (time.perf_counter() - t0) / reps


def bench(sizes=PAPER_SIZES, batches=(256, 1024), reps=10, seed=0):
    folded = random_folded(sizes, seed=seed)
    ecfg = ensemble.EnsembleConfig()
    pipe = deploy(folded, ens_cfg=ecfg).pipeline()
    baseline = make_baseline(folded, pipe.head)

    rng = np.random.default_rng(seed + 1)
    results = []
    for b in batches:
        x = jnp.asarray(rng.choice([-1.0, 1.0], (b, sizes[0])), jnp.float32)
        v_fused = np.asarray(pipe.run(x, VOTES))
        v_base = np.asarray(baseline(x))
        np.testing.assert_array_equal(v_fused, v_base)  # bit-exact gate

        t_fused = _time(lambda z: pipe.run(z, VOTES), x, reps)
        t_base = _time(baseline, x, reps)
        results.append({
            "batch": int(b),
            "bit_exact": True,
            "fused_s": t_fused,
            "baseline_s": t_base,
            "fused_inf_per_s": b / t_fused,
            "baseline_inf_per_s": b / t_base,
            "speedup": t_base / t_fused,
        })
    return folded, pipe, results


def main(fast: bool = False, json_path: str | None = None, reps: int = 10,
         write_json: bool = True):
    """write_json=False (benchmarks.run) returns rows without touching
    BENCH_e2e.json — the committed trajectory file is only (re)written by
    running this module directly."""
    sizes = PAPER_SIZES
    batches = (256,) if fast else (256, 1024, 4096)
    print("# e2e throughput: batch,impl,inf_per_s,seconds_per_batch,speedup")
    folded, pipe, results = bench(
        sizes=sizes, batches=batches, reps=max(3, reps // 2) if fast else reps
    )
    for r in results:
        print(f"e2e,{r['batch']},fused-{pipe.impl},"
              f"{r['fused_inf_per_s']:.0f},{r['fused_s']:.6f},"
              f"{r['speedup']:.2f}x")
        print(f"e2e,{r['batch']},baseline-unpacked,"
              f"{r['baseline_inf_per_s']:.0f},{r['baseline_s']:.6f},1.00x")

    record = {
        "schema": "picbnn-bench-e2e/v1",
        "model": {"layer_sizes": list(sizes),
                  "n_passes": ensemble.EnsembleConfig().n_passes},
        "pipeline_impl": pipe.impl,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "jax_version": jax.__version__,
        "reps": reps,
        "results": results,
        "min_speedup": min(r["speedup"] for r in results),
        "max_speedup": max(r["speedup"] for r in results),
    }
    if write_json:
        out = Path(json_path) if json_path else REPO_ROOT / "BENCH_e2e.json"
        out.write_text(json.dumps(record, indent=2) + "\n")
        print(f"# wrote {out} (min speedup {record['min_speedup']:.2f}x)")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None, help="output path override")
    ap.add_argument("--reps", type=int, default=10)
    args = ap.parse_args()
    main(fast=args.fast, json_path=args.json, reps=args.reps)

"""Table II reproduction: throughput / power / efficiency of the silicon,
derived from the bank-mapping cycle/energy model.

Paper figures: 25 MHz, 0.8 mW, 560 K inf/s (MNIST MLP, 33 output passes),
703 M inf/s/W, 184 TOPS/W-class efficiency.

Output CSV: metric,model,value,paper_value
"""

from __future__ import annotations

from repro.core import mapping
from repro.core.device_model import (
    CLOCK_HZ,
    EnergyModel,
    INFERENCES_PER_S_PER_W,
    MNIST_INFERENCES_PER_S,
    PICBNN_POWER_MW,
)


def analyze(name: str, sizes, n_passes: int = 33):
    plans = [
        mapping.plan_layer(sizes[i + 1], sizes[i], bias_cells=64)
        for i in range(len(sizes) - 1)
    ]
    cost = mapping.model_inference_cost(plans, n_output_passes=n_passes)
    e = EnergyModel()
    rows = []
    rows.append(("throughput_inf_per_s", name, cost.inferences_per_s,
                 MNIST_INFERENCES_PER_S if name == "mnist" else ""))
    rows.append(("energy_per_inference_nj", name, cost.energy_j * 1e9, ""))
    rows.append(("inf_per_s_per_w", name, 1.0 / cost.energy_j,
                 INFERENCES_PER_S_PER_W if name == "mnist" else ""))
    rows.append(("cycles_per_inference", name, cost.cycles, ""))
    rows.append(("binary_ops_per_inference", name, cost.binary_ops, ""))
    ops_rate = cost.binary_ops / cost.latency_s
    rows.append(("effective_tops", name, ops_rate / 1e12, ""))
    rows.append(("tops_per_w", name,
                 ops_rate / 1e12 / (PICBNN_POWER_MW * 1e-3), ""))
    return rows


def main():
    print("# Table II reproduction: metric,model,value,paper_value")
    rows = analyze("mnist", (784, 128, 10))
    rows += analyze("hand-gesture", (4096, 128, 20))
    for r in rows:
        val = f"{r[2]:.6g}"
        paper = f"{r[3]:.6g}" if r[3] != "" else ""
        print(f"table2,{r[0]},{r[1]},{val},{paper}")
    return rows


if __name__ == "__main__":
    main()

"""Benchmark runner: one section per paper table/figure + framework
benchmarks.  ``python -m benchmarks.run [--fast] [--json out.json]``
prints CSV rows and optionally writes the same results machine-readable.

Sections:
  fig5     — accuracy vs output-layer executions (paper Fig. 5)
  table2   — silicon throughput/power model (paper Table II)
  kern     — Pallas kernel microbench + TPU memory-roofline derivations
  roofline — the 40-cell dry-run roofline table (§Roofline source)
  e2e      — fused-pipeline vs layer-by-layer end-to-end throughput
  conv     — end-to-end binary CNN: fused conv pipeline vs unpacked
             layer-by-layer + accuracy-vs-passes on 28x28/64x64
  noise    — silicon-noise robustness curves + fused-MC vs faithful speedup
  serve    — classification serving engine under closed/open-loop load

JSON schema (picbnn-bench/v1): {"schema", "meta": {...}, "sections":
{name: [row, ...]}} where each row is the section's CSV tuple as a list
(the e2e section emits measurement dicts instead of CSV tuples).
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def _rows_jsonable(rows):
    return [list(r) if isinstance(r, (tuple, list)) else r for r in rows]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset: "
                         "fig5,table2,kern,roofline,e2e,conv,noise,serve")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (sections -> rows)")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    t0 = time.time()
    from benchmarks import (
        accuracy,
        conv_throughput,
        e2e_throughput,
        kernels_bench,
        noise_robustness,
        roofline_table,
        serve_load,
        table2,
    )

    sections: dict[str, list] = {}
    if only is None or "table2" in only:
        sections["table2"] = _rows_jsonable(table2.main())
    if only is None or "kern" in only:
        sections["kern"] = _rows_jsonable(kernels_bench.main(fast=args.fast))
    if only is None or "roofline" in only:
        sections["roofline"] = _rows_jsonable(roofline_table.main())
    if only is None or "e2e" in only:
        # rows only — the committed BENCH_e2e.json trajectory file is
        # written solely by `python -m benchmarks.e2e_throughput`
        sections["e2e"] = _rows_jsonable(
            e2e_throughput.main(fast=args.fast, write_json=False)
        )
    if only is None or "conv" in only:
        # dict rows — the committed BENCH_conv.json trajectory file is
        # written solely by `python -m benchmarks.conv_throughput`
        sections["conv"] = conv_throughput.main(fast=args.fast,
                                                write_json=False)
    if only is None or "noise" in only:
        # rows only — the committed BENCH_noise.json trajectory file is
        # written solely by `python -m benchmarks.noise_robustness`
        sections["noise"] = _rows_jsonable(
            noise_robustness.main(fast=args.fast, write_json=False)
        )
    if only is None or "serve" in only:
        # dict rows — the committed BENCH_serve.json trajectory file is
        # written solely by `python -m benchmarks.serve_load`
        sections["serve"] = serve_load.main(fast=args.fast,
                                            write_json=False)
    if only is None or "fig5" in only:
        sections["fig5"] = _rows_jsonable(accuracy.main(fast=args.fast))
    elapsed = time.time() - t0
    print(f"# benchmarks done in {elapsed:.1f}s")

    if args.json:
        import jax

        record = {
            "schema": "picbnn-bench/v1",
            "meta": {
                "fast": args.fast,
                "elapsed_s": round(elapsed, 2),
                "backend": jax.default_backend(),
                "platform": platform.platform(),
                "python": sys.version.split()[0],
                "jax_version": jax.__version__,
            },
            "sections": sections,
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.json}")
    return sections


if __name__ == "__main__":
    main()

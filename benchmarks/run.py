"""Benchmark runner: one section per paper table/figure + framework
benchmarks.  ``python -m benchmarks.run [--fast]`` prints CSV rows.

Sections:
  fig5     — accuracy vs output-layer executions (paper Fig. 5)
  table2   — silicon throughput/power model (paper Table II)
  kern     — Pallas kernel microbench + TPU memory-roofline derivations
  roofline — the 40-cell dry-run roofline table (§Roofline source)
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset: fig5,table2,kern,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    t0 = time.time()
    from benchmarks import accuracy, kernels_bench, roofline_table, table2

    if only is None or "table2" in only:
        table2.main()
    if only is None or "kern" in only:
        kernels_bench.main(fast=args.fast)
    if only is None or "roofline" in only:
        roofline_table.main()
    if only is None or "fig5" in only:
        accuracy.main(fast=args.fast)
    print(f"# benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()

"""End-to-end binary CNN benchmark: fused packed-domain conv pipeline vs
the layer-by-layer unpacked baseline, plus accuracy-vs-passes curves.

The conv analogue of `benchmarks/e2e_throughput.py`, covering the
paper's *end-to-end* binarization claim on the workload family the
related work targets (XNORBIN / ChewBaccaNN binary-CNN datapaths):

  baseline — the pre-pipeline deployed path: per conv layer, channel-
             pack the ±1 float feature map (shift-broadcast pack),
             per-tap XOR-popcount accumulation, +C, sign back to ±1
             floats — activations round-trip through the unpacked
             domain between every layer, ops dispatch eagerly — then
             the flattened FC stage and fused head vote.
  fused    — the conv pipeline (`configs.paper_cnn.build_cnn_pipeline`):
             one compiled program from raw [0,1] pixels (thermometer
             input encoding inside) to int32 votes, activations packed
             uint32 end to end.

Both paths are verified vote-identical before timing on BOTH input
sizes (28x28 MNIST-shape and 64x64 HG-shape — the acceptance bar).
The accuracy section trains the small binary CNNs on the synthetic
datasets and reports Algorithm-1 accuracy as a function of the pass
count (the Fig.-5 sweep, conv edition) via the noiseless truncated-
sweep identity `ensemble.sweep_from_votes`.

Results are emitted as `BENCH_conv.json` at the repo root (schema
picbnn-bench-conv/v1) so the perf trajectory is machine-readable
across PRs.

Run:  PYTHONPATH=src python -m benchmarks.conv_throughput [--fast]
"""

from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.spec import VOTES  # the one spec this benchmark times
from repro.configs.paper_cnn import HG_CNN, MNIST_CNN, build_cnn_pipeline
from repro.core import binarize, convnet, ensemble
from repro.core.convnet import CNNConfig
from repro.data.synthetic import HG_LIKE, MNIST_LIKE, make_dataset
from repro.kernels import fused_conv

REPO_ROOT = Path(__file__).resolve().parents[1]



def make_baseline(cfg: CNNConfig, folded, head):
    """The pre-pipeline layer-by-layer unpacked deployed CNN (eager).

    Every conv layer crosses the packed/unpacked boundary twice (float
    sign activations -> shift-broadcast channel pack -> packed per-tap
    XNOR-popcount -> float sign), exactly the round trips the fused
    pipeline removes; the FC stage mirrors e2e_throughput's baseline.
    """
    conv_layers = [l for l in folded if isinstance(l, convnet.FoldedConvLayer)]
    fc_layers = [l for l in folded
                 if not isinstance(l, convnet.FoldedConvLayer)]
    metas = fused_conv.conv_metas_for(conv_layers, cfg.side)
    conv_ws = [fused_conv.pack_conv_rows(l) for l in conv_layers]
    conv_cs = [jnp.asarray(l.c, jnp.int32) for l in conv_layers]
    fc_ws = [
        binarize.pack_bits(jnp.asarray((l.weights_pm1 > 0).astype(np.uint8)))
        for l in fc_layers[:-1]
    ]
    fc_cs = [jnp.asarray(l.c, jnp.int32) for l in fc_layers[:-1]]
    fc_nb = [l.n_in for l in fc_layers[:-1]]

    def baseline(x01):
        h = cfg.encoding.encode_pm1(
            jnp.asarray(x01).reshape(-1, cfg.side, cfg.side)
        )  # ±1 float feature map [B, S, S, E]
        for w, c, m in zip(conv_ws, conv_cs, metas):
            # activations leave the binary domain every layer: pack the
            # ±1 floats, search (shared tap geometry — the same helper
            # the fused kernel uses), sign back to floats
            xp = binarize.pack_bits_reference(binarize.to_bits(h))
            hd = fused_conv.conv_hd_packed(xp, w, m)
            y = (m.n_bits - 2 * hd) + c[None, None, None, :]
            h = jnp.where(y >= 0, 1.0, -1.0)
        h = h.reshape(h.shape[0], -1)  # NHWC flatten, ±1 floats
        for w, c, nb in zip(fc_ws, fc_cs, fc_nb):
            xp = binarize.pack_bits_reference(binarize.to_bits(h))
            hd = binarize.hamming_packed(xp[:, None, :], w)
            y = (nb - 2 * hd) + c[None, :]
            h = jnp.where(y >= 0, 1.0, -1.0)
        return ensemble.votes_fused(head, h)

    return baseline


def _time(fn, x, reps):
    jax.block_until_ready(fn(x))  # warm / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(x))
    return (time.perf_counter() - t0) / reps


def bench_throughput(cfg: CNNConfig, name: str, batches, reps, seed=0):
    """Bit-exactness gate + fused-vs-baseline timing for one config."""
    folded = convnet.random_folded_cnn(cfg, seed=seed)
    pipe = build_cnn_pipeline(cfg, folded, impl=None)
    baseline = make_baseline(cfg, folded, pipe.head)
    rng = np.random.default_rng(seed + 1)
    rows = []
    for b in batches:
        x = rng.random((b, cfg.n_in)).astype(np.float32)
        v_fused = np.asarray(pipe.run(x, VOTES))
        v_base = np.asarray(baseline(x))
        np.testing.assert_array_equal(v_fused, v_base)  # bit-exact gate
        t_fused = _time(lambda z: pipe.run(z, VOTES), x, reps)
        t_base = _time(baseline, x, reps)
        rows.append({
            "model": name,
            "batch": int(b),
            "bit_exact": True,
            "fused_s": t_fused,
            "baseline_s": t_base,
            "fused_inf_per_s": b / t_fused,
            "baseline_inf_per_s": b / t_base,
            "speedup": t_base / t_fused,
        })
    return rows


def bench_accuracy(cfg: CNNConfig, name: str, spec, *, n_train, n_test,
                   epochs, pass_points=(1, 5, 9, 17, 33), seed=0):
    """Train the binary CNN on synthetic data; accuracy vs pass count."""
    tx, ty, vx, vy = make_dataset(spec, n_train=n_train, n_test=n_test,
                                  seed=seed)
    params = convnet.train_cnn(jax.random.PRNGKey(seed), cfg, tx, ty,
                               epochs=epochs)
    sw = convnet.eval_cnn_accuracy(params, cfg, vx, vy)["top1"]
    pipe = build_cnn_pipeline(cfg, convnet.fold_cnn(params, cfg))
    votes = pipe.run(jnp.asarray(vx), VOTES)
    n_passes = int(pipe.head.thresholds.shape[0])
    # noiseless truncated-sweep identity: the whole Fig.-5-style curve
    # from ONE fused pass (sweep_from_votes is noiseless-only)
    cum = ensemble.sweep_from_votes(votes, n_passes)
    acc = ensemble.accuracy_from_cumulative(cum, vy, topk=(1,))
    curve = {int(p): acc[min(p, n_passes)]["top1"] for p in pass_points}
    return {
        "model": name,
        "n_train": n_train,
        "n_test": n_test,
        "epochs": epochs,
        "software_top1": sw,
        "deployed_top1_by_passes": curve,
        "silicon_equiv_inf_per_s":
            convnet.cnn_inference_cost(cfg, n_passes).inferences_per_s,
    }


def main(fast: bool = False, json_path: str | None = None, reps: int = 10,
         write_json: bool = True):
    """write_json=False (benchmarks.run) returns rows without touching
    BENCH_conv.json — the committed trajectory file is only (re)written
    by running this module directly."""
    reps = max(3, reps // 2) if fast else reps
    batches = (64,) if fast else (64, 256)
    print("# conv throughput: model,batch,impl,inf_per_s,sec_per_batch,"
          "speedup")
    thr_rows = []
    # both input sizes run even in fast mode — the acceptance bar wants
    # bit-exactness + speedup on >= 2 sizes (64x64 at batch 64 only)
    sizes = [(MNIST_CNN, "cnn-mnist-28"), (HG_CNN, "cnn-hg-64")]
    for cfg, name in sizes:
        rows = bench_throughput(
            cfg, name, batches if cfg.side <= 28 else batches[:1], reps
        )
        thr_rows += rows
        for r in rows:
            print(f"conv,{r['model']},{r['batch']},fused,"
                  f"{r['fused_inf_per_s']:.0f},{r['fused_s']:.6f},"
                  f"{r['speedup']:.2f}x")
            print(f"conv,{r['model']},{r['batch']},baseline-unpacked,"
                  f"{r['baseline_inf_per_s']:.0f},{r['baseline_s']:.6f},"
                  f"1.00x")

    print("# conv accuracy vs passes (synthetic data, trained binary CNN)")
    acc_rows = [
        bench_accuracy(
            MNIST_CNN, "cnn-mnist-28", MNIST_LIKE,
            n_train=800 if fast else 4000,
            n_test=200 if fast else 800,
            epochs=2 if fast else 6,
        )
    ]
    if not fast:
        acc_rows.append(bench_accuracy(
            HG_CNN, "cnn-hg-64", HG_LIKE,
            n_train=1500, n_test=300, epochs=4,
        ))
    for r in acc_rows:
        curve = ",".join(f"p{p}={a:.3f}"
                         for p, a in r["deployed_top1_by_passes"].items())
        print(f"acc,{r['model']},software={r['software_top1']:.3f},{curve}")

    record = {
        "schema": "picbnn-bench-conv/v1",
        "models": {
            name: {
                "side": cfg.side,
                "encoding": [cfg.encoding.kind, cfg.encoding.width],
                "conv": [[s.k, s.c_out, s.stride] for s in cfg.conv],
                "hidden": list(cfg.hidden),
                "n_classes": cfg.n_classes,
                "flat_features": cfg.flat_features,
            }
            for cfg, name in ((MNIST_CNN, "cnn-mnist-28"),
                              (HG_CNN, "cnn-hg-64"))
        },
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "jax_version": jax.__version__,
        "reps": reps,
        "throughput": thr_rows,
        "accuracy": acc_rows,
        "min_speedup": min(r["speedup"] for r in thr_rows),
        "max_speedup": max(r["speedup"] for r in thr_rows),
    }
    if write_json:
        out = Path(json_path) if json_path else REPO_ROOT / "BENCH_conv.json"
        out.write_text(json.dumps(record, indent=2) + "\n")
        print(f"# wrote {out} (min speedup {record['min_speedup']:.2f}x)")
    return {"throughput": thr_rows, "accuracy": acc_rows}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None, help="output path override")
    ap.add_argument("--reps", type=int, default=10)
    args = ap.parse_args()
    main(fast=args.fast, json_path=args.json, reps=args.reps)

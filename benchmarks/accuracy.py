"""Fig. 5 reproduction: TOP-1/TOP-2 accuracy vs output-layer executions.

The paper sweeps the number of fully-connected output-layer executions
(1..33, HD thresholds {0,2,...,64}) and reports MNIST / Hand-Gesture
accuracy converging to (near) the software baseline.  We reproduce the
sweep on synthetic drop-in datasets under three conditions:
  * noiseless compare (TPU semantics / fused kernel),
  * silicon-like PVT noise (NoiseModel),
  * the hierarchical (strictly binary) input-layer mode.

Output: CSV rows  dataset,mode,n_passes,top1,top2
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import pipeline
from repro.core import bnn, ensemble, mapping
from repro.core.device_model import SILICON
from repro.data.synthetic import (
    HG_LIKE,
    MNIST_LIKE,
    binarize_images,
    make_dataset,
)


def run_dataset(name: str, spec, hidden: int, epochs: int, seed: int = 0,
                noise: float = 0.7):
    """noise=0.7 calibrates the synthetic MNIST-like task so the fp32
    software baseline lands at ~95% — the paper's MNIST operating point —
    making the binary-vs-baseline gap comparable to Fig. 5."""
    cfg = bnn.MLPConfig(
        layer_sizes=(spec.n_pixels, hidden, spec.n_classes), bias_cells=64
    )
    tx, ty, vx, vy = make_dataset(
        spec, n_train=6000, n_test=1500, seed=seed, noise=noise
    )
    txb, vxb = binarize_images(tx), binarize_images(vx)
    params = bnn.train_mlp(
        jax.random.PRNGKey(seed), cfg, txb, ty, epochs=epochs, batch=128,
        lr=2e-3,
    )
    sw = bnn.eval_accuracy(params, cfg, vxb, vy, topk=(1, 2))
    rows = [
        (name, "software-fp-logits", 0, sw["top1"], sw["top2"]),
    ]

    folded = bnn.fold(params, cfg)
    mapped = [mapping.map_layer(l, cfg.bias_cells) for l in folded[:-1]]

    # noiseless: ONE fused end-to-end packed-domain pipeline pass; the
    # whole truncated-threshold sweep is recovered from the fused vote
    # totals (ensemble.sweep_from_votes) instead of 33 re-searches.
    ecfg = ensemble.EnsembleConfig()
    pipe = pipeline.compile_pipeline(folded, ecfg)
    votes = pipe.votes(jnp.asarray(vxb))
    cum = ensemble.sweep_from_votes(votes, ecfg.n_passes)
    sweep = ensemble.accuracy_from_cumulative(cum, vy)
    for p in (1, 3, 5, 9, 17, 25, 33):
        rows.append((name, "noiseless", p, sweep[p]["top1"], sweep[p]["top2"]))

    # noise / strictly-binary modes keep the faithful CAM-tile flow
    for mode_name, layer_mode, noise in [
        ("silicon-noise", "exact", SILICON),
        ("binary-hierarchical", "hierarchical", None),
    ]:
        h = jnp.asarray(vxb)
        for ml in mapped:
            h = mapping.layer_forward(ml, h, layer_mode)
        ecfg = ensemble.EnsembleConfig(
            noise=noise or ensemble.EnsembleConfig().noise
        )
        head = ensemble.build_head(folded[-1], ecfg)
        key = jax.random.PRNGKey(seed + 1) if noise else None
        sweep = ensemble.accuracy_sweep(
            head, h, jnp.asarray(vy), ecfg, key=key
        )
        for p in (1, 3, 5, 9, 17, 25, 33):
            rows.append(
                (name, mode_name, p, sweep[p]["top1"], sweep[p]["top2"])
            )
    return rows


def main(fast: bool = False):
    print("# Fig5 reproduction: dataset,mode,n_passes,top1,top2")
    t0 = time.time()
    rows = run_dataset("mnist-like", MNIST_LIKE, 128,
                       epochs=3 if fast else 8, noise=0.7)
    if not fast:
        rows += run_dataset("hg-like", HG_LIKE, 128, epochs=6, noise=0.6)
    for r in rows:
        print(f"fig5,{r[0]},{r[1]},{r[2]},{r[3]:.4f},{r[4]:.4f}")
    print(f"# fig5 done in {time.time() - t0:.1f}s")
    return rows


if __name__ == "__main__":
    main()

"""Fig. 5 reproduction: TOP-1/TOP-2 accuracy vs output-layer executions.

The paper sweeps the number of fully-connected output-layer executions
(1..33, HD thresholds {0,2,...,64}) and reports MNIST / Hand-Gesture
accuracy converging to (near) the software baseline.  We reproduce the
sweep on synthetic drop-in datasets under three conditions:
  * noiseless compare (TPU semantics / fused kernel),
  * silicon-like PVT noise — the fused physics-threaded pipeline
    (`compile_pipeline(..., noise=SILICON)`), Monte-Carlo over seeds via
    `cum_votes` at fused speed (the sequential `votes_faithful` loop this
    replaces is timed against it in benchmarks/noise_robustness.py),
  * the hierarchical (strictly binary) input-layer mode.

Output: CSV rows  dataset,mode,n_passes,top1,top2
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import pipeline
from repro.core import bnn, ensemble, mapping
from repro.core.device_model import SILICON
from repro.deploy import deploy
from repro.spec import VOTES, InferenceSpec
from repro.data.synthetic import (
    HG_LIKE,
    MNIST_LIKE,
    binarize_images,
    make_dataset,
)

#: the silicon truncated-sweep request this benchmark Monte-Carlos
CUM_SILICON = InferenceSpec(noise="batch", cumulative=True)


def _sweep_noiseless_fused(pipe: "pipeline.CompiledPipeline", votes, n_passes):
    """Guarded `sweep_from_votes`: valid ONLY for a noiseless pipeline.

    The staircase reconstruction breaks under sampled thresholds (see
    ensemble.sweep_from_votes / DESIGN.md §8); silicon-mode sweeps must go
    through the cumulative spec (`InferenceSpec(noise="batch",
    cumulative=True)`) instead.
    """
    assert pipe.physics is None or pipe.physics.is_noiseless, (
        "sweep_from_votes is noiseless-only; run the cumulative silicon "
        "spec for silicon-mode truncated sweeps"
    )
    return ensemble.sweep_from_votes(votes, n_passes)


def run_dataset(name: str, spec, hidden: int, epochs: int, seed: int = 0,
                noise: float = 0.7):
    """noise=0.7 calibrates the synthetic MNIST-like task so the fp32
    software baseline lands at ~95% — the paper's MNIST operating point —
    making the binary-vs-baseline gap comparable to Fig. 5."""
    cfg = bnn.MLPConfig(
        layer_sizes=(spec.n_pixels, hidden, spec.n_classes), bias_cells=64
    )
    tx, ty, vx, vy = make_dataset(
        spec, n_train=6000, n_test=1500, seed=seed, noise=noise
    )
    txb, vxb = binarize_images(tx), binarize_images(vx)
    params = bnn.train_mlp(
        jax.random.PRNGKey(seed), cfg, txb, ty, epochs=epochs, batch=128,
        lr=2e-3,
    )
    sw = bnn.eval_accuracy(params, cfg, vxb, vy, topk=(1, 2))
    rows = [
        (name, "software-fp-logits", 0, sw["top1"], sw["top2"]),
    ]

    folded = bnn.fold(params, cfg)
    mapped = [mapping.map_layer(l, cfg.bias_cells) for l in folded[:-1]]

    # noiseless: ONE fused end-to-end packed-domain pipeline pass; the
    # whole truncated-threshold sweep is recovered from the fused vote
    # totals (ensemble.sweep_from_votes, noiseless-only — guarded)
    # instead of 33 re-searches.
    ecfg = ensemble.EnsembleConfig()
    pipe = deploy(folded, ens_cfg=ecfg).pipeline()
    votes = pipe.run(jnp.asarray(vxb), VOTES)
    cum = _sweep_noiseless_fused(pipe, votes, ecfg.n_passes)
    sweep = ensemble.accuracy_from_cumulative(cum, vy)
    for p in (1, 3, 5, 9, 17, 25, 33):
        rows.append((name, "noiseless", p, sweep[p]["top1"], sweep[p]["top2"]))

    # silicon PVT noise: the SAME fused pipeline with the device physics
    # threaded through (sampled per-pass thresholds), Monte-Carlo over
    # seeds — per-pass trajectories via cum_votes at fused speed.
    n_mc = 2 if epochs <= 3 else 4
    pipe_si = deploy(folded, ens_cfg=ecfg, noise=SILICON).pipeline()
    acc = {}
    for i in range(n_mc):
        cum = pipe_si.run(jnp.asarray(vxb), CUM_SILICON,
                          key=jax.random.PRNGKey(seed + 1 + i))
        s = ensemble.accuracy_from_cumulative(cum, vy)
        for p, d in s.items():
            for k, v in d.items():
                acc.setdefault(p, {}).setdefault(k, []).append(v)
    for p in (1, 3, 5, 9, 17, 25, 33):
        rows.append((name, "silicon-noise", p,
                     float(np.mean(acc[p]["top1"])),
                     float(np.mean(acc[p]["top2"]))))

    # strictly-binary hierarchical mode keeps the faithful CAM-tile flow
    h = jnp.asarray(vxb)
    for ml in mapped:
        h = mapping.layer_forward(ml, h, "hierarchical")
    head = ensemble.build_head(folded[-1], ecfg)
    sweep = ensemble.accuracy_sweep(head, h, jnp.asarray(vy), ecfg)
    for p in (1, 3, 5, 9, 17, 25, 33):
        rows.append(
            (name, "binary-hierarchical", p, sweep[p]["top1"], sweep[p]["top2"])
        )
    return rows


def main(fast: bool = False):
    print("# Fig5 reproduction: dataset,mode,n_passes,top1,top2")
    t0 = time.time()
    rows = run_dataset("mnist-like", MNIST_LIKE, 128,
                       epochs=3 if fast else 8, noise=0.7)
    if not fast:
        rows += run_dataset("hg-like", HG_LIKE, 128, epochs=6, noise=0.6)
    for r in rows:
        print(f"fig5,{r[0]},{r[1]},{r[2]},{r[3]:.4f},{r[4]:.4f}")
    print(f"# fig5 done in {time.time() - t0:.1f}s")
    return rows


if __name__ == "__main__":
    main()

"""Fig. 6-style silicon-noise robustness sweep (beyond-paper figure).

The paper's LLN argument (Sec. IV) claims the 33-pass majority vote
recovers the software logit ranking *under analog PVT noise*.  This
benchmark quantifies that claim as a robustness curve: top-1 accuracy of
the fused silicon-mode pipeline versus noise magnitude, mean ± band over
seeds, evaluated by Monte-Carlo through
the batch-draw Monte-Carlo spec (`InferenceSpec(noise="batch",
mc_samples=S)`; Hamming distances computed once, sampled thresholds
vmapped — the physics-threaded fast path).

Deployed net: a random folded paper-shape MLP; ground truth is the
full-precision logit argmax of the SAME net, so the metric isolates
exactly the paper's claim (binary vote ranking == software logit ranking)
from dataset/training effects, and the run is deterministic given seeds —
the fast slice doubles as a CI check (scripts/smoke.sh).

Also measured and recorded in BENCH_noise.json (picbnn-bench-noise/v1):
  * the fused-MC vs sequential-`votes_faithful` speedup at equal sample
    count (the slow path this pipeline replaces; acceptance bar >= 5x);
  * the LLN headline on the random net: mean SILICON logit-ranking
    recovery at 33 passes vs noiseless — a deliberately harsh metric
    (random nets have near-zero margins, so every tie counts against it);
  * `trained_lln` (full run only): the same comparison on a TRAINED
    Fig.-5 MNIST-like net — the setting the paper's "within ~1 point"
    claim is about (margins are real, the 33-pass majority absorbs the
    noise).

Run:  PYTHONPATH=src python -m benchmarks.noise_robustness [--fast]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ensemble
from repro.core.device_model import SILICON, NoiseModel
from repro.deploy import deploy
from repro.spec import VOTES, InferenceSpec
from benchmarks.e2e_throughput import PAPER_SIZES, random_folded


def _mc_spec(n_mc: int) -> InferenceSpec:
    """The batch-draw Monte-Carlo request this benchmark sweeps."""
    return InferenceSpec(noise="batch", mc_samples=int(n_mc))

REPO_ROOT = Path(__file__).resolve().parents[1]


def _fp_labels(folded, x_pm1):
    """Software ground truth: full-precision logit argmax of the net."""
    h = jnp.asarray(x_pm1, jnp.float32)
    for layer in folded[:-1]:
        y = h @ jnp.asarray(layer.weights_pm1.T, jnp.float32) + jnp.asarray(
            layer.c, jnp.float32
        )
        h = jnp.where(y >= 0, 1.0, -1.0)
    out = folded[-1]
    logits = h @ jnp.asarray(out.weights_pm1.T, jnp.float32) + jnp.asarray(
        out.c, jnp.float32
    )
    return np.asarray(jnp.argmax(logits, -1)), h


def _mc_accuracy(pipe, x, labels, seeds, n_mc):
    """Mean / band of top-1 accuracy over seeds, n_mc MC draws each."""
    per_seed = []
    for s in seeds:
        votes = np.asarray(
            pipe.run(x, _mc_spec(n_mc), key=jax.random.PRNGKey(s))
        )
        per_seed.append((votes.argmax(-1) == labels[None]).mean())
    return float(np.mean(per_seed)), float(np.std(per_seed))


def bench(sizes=PAPER_SIZES, batch=512, n_mc=64, n_seeds=4,
          sigma_hd_grid=(0.0, 0.5, 1.0, 2.0, 4.0),
          drift_grid=(-8.0, -4.0, 0.0, 4.0, 8.0), seed=0):
    folded = random_folded(sizes, seed=seed)
    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(rng.choice([-1.0, 1.0], (batch, sizes[0])), jnp.float32)
    labels, hidden_pm1 = _fp_labels(folded, x)
    seeds = list(range(100, 100 + n_seeds))

    pipe_nl = deploy(folded).pipeline()
    acc_noiseless = float(
        (np.asarray(pipe_nl.run(x, VOTES)).argmax(-1) == labels).mean()
    )

    rows = [("noise", "noiseless", 0.0, acc_noiseless, 0.0)]
    curves = {"sigma_hd": [], "temp_drift_hd": []}
    # accuracy vs per-row HD noise (all other sigmas off: isolate one axis)
    for s_hd in sigma_hd_grid:
        nm = NoiseModel(sigma_hd=float(s_hd), sigma_vref=0.0,
                        sigma_tjitter=0.0)
        pipe = deploy(folded, noise=nm).pipeline()
        mean, band = _mc_accuracy(pipe, x, labels, seeds, n_mc)
        curves["sigma_hd"].append(
            {"sigma_hd": float(s_hd), "top1_mean": mean, "top1_std": band}
        )
        rows.append(("noise", "sigma_hd", float(s_hd), mean, band))
    # accuracy vs systematic drift ON TOP of silicon-default randomness —
    # the TDC-competitor failure mode the paper contrasts against
    for d in drift_grid:
        nm = dataclasses.replace(SILICON, temp_drift_hd=float(d))
        pipe = deploy(folded, noise=nm).pipeline()
        mean, band = _mc_accuracy(pipe, x, labels, seeds, n_mc)
        curves["temp_drift_hd"].append(
            {"temp_drift_hd": float(d), "top1_mean": mean, "top1_std": band}
        )
        rows.append(("noise", "temp_drift_hd", float(d), mean, band))

    # --- LLN headline: full SILICON model at 33 passes vs noiseless ------
    pipe_si = deploy(folded, noise=SILICON).pipeline()
    acc_si_mean, acc_si_band = _mc_accuracy(pipe_si, x, labels, seeds, n_mc)
    rows.append(("noise", "silicon-33pass", 0.0, acc_si_mean, acc_si_band))

    # --- fused-MC vs sequential votes_faithful at equal sample count -----
    key = jax.random.PRNGKey(7)
    n_time = n_mc
    mc = _mc_spec(n_time)
    jax.block_until_ready(pipe_si.run(x, mc, key=key))  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(pipe_si.run(x, mc, key=key))
    t_fused = time.perf_counter() - t0

    head = pipe_si.head
    phys = pipe_si.physics
    keys = jax.random.split(key, n_time)
    jax.block_until_ready(  # warm the eager path's caches too
        ensemble.votes_faithful(head, hidden_pm1, key=keys[0], physics=phys)
    )
    t0 = time.perf_counter()
    for k in keys:
        jax.block_until_ready(
            ensemble.votes_faithful(head, hidden_pm1, key=k, physics=phys)
        )
    t_faithful = time.perf_counter() - t0
    speedup = t_faithful / t_fused
    rows.append(("noise", "mc-speedup", float(n_time), speedup, 0.0))

    record = {
        "schema": "picbnn-bench-noise/v1",
        "model": {"layer_sizes": list(sizes), "batch": int(batch),
                  "n_passes": ensemble.EnsembleConfig().n_passes},
        "n_mc": int(n_mc),
        "n_seeds": int(n_seeds),
        "metric": "fp-logit-ranking recovery on a random net (harsh: "
                  "near-zero margins; see trained_lln for the Fig.-5 "
                  "setting)",
        "acc_noiseless": acc_noiseless,
        "acc_silicon_mean": acc_si_mean,
        "acc_silicon_std": acc_si_band,
        "ranking_delta_points": abs(acc_noiseless - acc_si_mean) * 100,
        "curves": curves,
        "speedup": {
            "n_samples": int(n_time),
            "fused_mc_s": t_fused,
            "faithful_loop_s": t_faithful,
            "speedup": speedup,
        },
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "jax_version": jax.__version__,
    }
    return rows, record


def trained_lln(n_mc=4, seed=0, epochs=6):
    """The paper's actual LLN claim: silicon vs noiseless on a TRAINED net.

    Trains the Fig.-5 synthetic-MNIST MLP and compares noiseless fused
    accuracy against the mean SILICON Monte-Carlo accuracy at the full 33
    passes.  Expected (and asserted in the slow test tier): within ~1
    point — trained margins are what the law of large numbers needs.
    """
    from repro.core import bnn
    from repro.data.synthetic import MNIST_LIKE, binarize_images, make_dataset

    cfg = bnn.MLPConfig(
        layer_sizes=(MNIST_LIKE.n_pixels, 128, MNIST_LIKE.n_classes),
        bias_cells=64,
    )
    tx, ty, vx, vy = make_dataset(
        MNIST_LIKE, n_train=6000, n_test=1500, seed=seed, noise=0.7
    )
    txb, vxb = binarize_images(tx), binarize_images(vx)
    params = bnn.train_mlp(
        jax.random.PRNGKey(seed), cfg, txb, ty, epochs=epochs, batch=128,
        lr=2e-3,
    )
    folded = bnn.fold(params, cfg)
    labels = np.asarray(vy)
    x = jnp.asarray(vxb)

    pipe_nl = deploy(folded).pipeline()
    acc_nl = float(
        (np.asarray(pipe_nl.run(x, VOTES)).argmax(-1) == labels).mean()
    )
    pipe_si = deploy(folded, noise=SILICON).pipeline()
    votes = np.asarray(
        pipe_si.run(x, _mc_spec(n_mc), key=jax.random.PRNGKey(seed + 1))
    )
    acc_si = float((votes.argmax(-1) == labels[None]).mean())
    return {
        "acc_noiseless": acc_nl,
        "acc_silicon_mean": acc_si,
        "delta_points": abs(acc_nl - acc_si) * 100,
        "n_mc": int(n_mc),
        "epochs": int(epochs),
    }


def main(fast: bool = False, write_json: bool = True,
         json_path: str | None = None):
    print("# noise robustness: section,axis,value,top1_mean,top1_band")
    t0 = time.time()
    if fast:
        rows, record = bench(batch=128, n_mc=8, n_seeds=2,
                             sigma_hd_grid=(0.0, 1.0, 2.0),
                             drift_grid=(-4.0, 0.0, 4.0))
    else:
        rows, record = bench()
        record["trained_lln"] = t = trained_lln()
        rows.append(("noise", "trained-lln-delta-points", 33.0,
                     t["delta_points"], 0.0))
        print(f"# trained LLN: noiseless {t['acc_noiseless']:.4f} vs "
              f"silicon {t['acc_silicon_mean']:.4f} "
              f"(delta {t['delta_points']:.2f} points)")
    for r in rows:
        print(f"{r[0]},{r[1]},{r[2]},{r[3]:.4f},{r[4]:.4f}")
    print(f"# ranking recovery (random net): noiseless "
          f"{record['acc_noiseless']:.4f} vs silicon "
          f"{record['acc_silicon_mean']:.4f} at 33 passes "
          f"(delta {record['ranking_delta_points']:.2f} points)")
    print(f"# fused MC vs faithful loop: "
          f"{record['speedup']['speedup']:.1f}x at "
          f"{record['speedup']['n_samples']} samples")
    print(f"# noise robustness done in {time.time() - t0:.1f}s")
    if write_json:
        out = Path(json_path) if json_path else REPO_ROOT / "BENCH_noise.json"
        out.write_text(json.dumps(record, indent=2) + "\n")
        print(f"# wrote {out}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None, help="output path override")
    args = ap.parse_args()
    main(fast=args.fast, json_path=args.json)

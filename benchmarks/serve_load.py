"""Serving-engine load benchmark: closed- and open-loop traffic against
the PiC-BNN classification server (serve/picbnn.py).

What it answers: how much of the raw fused-pipeline batch throughput
does the serving layer keep once requests arrive one image at a time and
must be coalesced, staged, and fanned out — and what latency do clients
see as offered load approaches saturation?

  raw         — the noiseless vote spec timed at exactly max_batch (the upper
                bound: zero scheduling, zero per-request bookkeeping).
  closed loop — N client threads, each keeping a window of W requests
                outstanding (submit W, collect, repeat).  Saturates the
                engine; `sustained / raw` is the serving efficiency the
                acceptance bar cares about (>= 0.7 at saturation).
  open loop   — a pacing thread offers requests at a fixed rate
                (1 ms-tick bursts) regardless of completions, swept over
                fractions of the measured saturation throughput;
                p50/p95/p99 latency per offered-load point shows the
                hockey-stick as the queue starts to build.

The paper's 560 K inf/s silicon figure (via
`mapping.model_inference_cost` on the same 784-128-10 deployment) is
reported alongside for context — the TPU/CPU translation serves a
different regime (batched throughput vs the macro's fixed 45-cycle
pipeline), so the ratio is context, not a claim.

Results land in `BENCH_serve.json` at the repo root (schema
picbnn-bench-serve/v1) when run directly:

  PYTHONPATH=src python -m benchmarks.serve_load [--smoke] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import platform
import threading
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import bnn, ensemble, mapping
from repro.deploy import deploy
from repro.serve.picbnn import BatchingPolicy, PicBnnServer
from repro.serve.scheduler import latency_summary
from repro.spec import VOTES

REPO_ROOT = Path(__file__).resolve().parents[1]

PAPER_SIZES = (784, 128, 10)


def random_folded(sizes, seed=0, cmax=40, bias_cells=64):
    rng = np.random.default_rng(seed)
    layers = []
    for i in range(len(sizes) - 1):
        n_in, n_out = sizes[i], sizes[i + 1]
        c = bnn.parity_adjust_c(
            rng.integers(-cmax, cmax + 1, n_out), n_in, bias_cells
        )
        layers.append(bnn.FoldedLayer(
            weights_pm1=rng.choice([-1, 1], (n_out, n_in)).astype(np.int8),
            c=c,
        ))
    return layers


def measure_raw(pipe, batch: int, duration_s: float, seed=1) -> dict:
    """The no-scheduler upper bound: jitted votes at exactly `batch`,
    back to back for `duration_s`.  SUSTAINED, not a rep burst: on a
    small shared host a fraction-of-a-second sample rides CPU burst
    credits and overstates what a serving loop could ever see (observed
    ~300 K inf/s for 0.3 s decaying to ~170 K sustained), so the upper
    bound is measured over the same window length as the load phases."""
    rng = np.random.default_rng(seed)
    x = rng.choice([-1.0, 1.0], (batch, PAPER_SIZES[0])).astype(np.float32)
    jax.block_until_ready(pipe.run(x, VOTES))  # compile
    t0 = time.perf_counter()
    n = 0
    while time.perf_counter() - t0 < duration_s:
        jax.block_until_ready(pipe.run(x, VOTES))
        n += 1
    dt = (time.perf_counter() - t0) / n
    return {"batch": batch, "s_per_batch": dt, "inf_per_s": batch / dt,
            "duration_s": duration_s}


def _fresh_server(dep, policy: BatchingPolicy) -> PicBnnServer:
    """New engine around the SAME Deployment — its cached pipeline's jit
    programs persist, so per-phase servers add no recompiles (and
    layer_sizes for the Table-II comparison derive from the artifact)."""
    srv = PicBnnServer(policy)
    srv.register("mnist", dep)
    return srv


def closed_loop(dep, policy: BatchingPolicy, n_clients: int, window: int,
                duration_s: float, images: np.ndarray,
                depth: int = 2) -> dict:
    """Each client keeps `depth` windows of `window` requests in flight
    (submit ahead, then wait the oldest) — saturation means a backlog
    exists, and the submit-ahead keeps the dispatch thread fed so no
    stage of the pipeline ever sleeps waiting for a client wake-up."""
    srv = _fresh_server(dep, policy)
    srv.warmup()
    stop = time.perf_counter() + duration_s

    def client(ci: int):
        rng = np.random.default_rng(100 + ci)
        start = int(rng.integers(0, len(images) - window))
        burst = images[start:start + window]
        pending = [srv.submit_many("mnist", burst) for _ in range(depth)]
        while time.perf_counter() < stop:
            pending.pop(0).wait_all(timeout=120)
            pending.append(srv.submit_many("mnist", burst))
        for gh in pending:
            gh.wait_all(timeout=120)

    with srv:
        threads = [threading.Thread(target=client, args=(ci,))
                   for ci in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    st = srv.stats()
    ms = st.per_model["mnist"]
    return {
        "clients": n_clients,
        "window": window,
        "duration_s": duration_s,
        "n_requests": st.n_requests,
        "inf_per_s": st.inf_per_s,
        "mean_batch": st.mean_batch,
        "mean_occupancy": st.mean_occupancy,
        "queue_high_water": st.queue_high_water,
        "p50_ms": ms.latency.p50_ms,
        "p95_ms": ms.latency.p95_ms,
        "p99_ms": ms.latency.p99_ms,
        "service_p50_ms": ms.service.p50_ms,
    }


def open_loop(dep, policy: BatchingPolicy, offered_inf_per_s: float,
              duration_s: float, images: np.ndarray) -> dict:
    """Paced submission at a fixed offered rate (1 ms-tick bursts)."""
    srv = _fresh_server(dep, policy)
    srv.warmup()
    n_img = len(images)
    submitted = 0
    with srv:
        t0 = time.perf_counter()
        while True:
            now = time.perf_counter()
            if now - t0 >= duration_s:
                break
            due = int((now - t0) * offered_inf_per_s)
            if submitted < due:
                # modular gather so a post-stall catch-up burst larger
                # than the pool still submits every request it counts
                idx = np.arange(submitted, due) % n_img
                srv.submit_many("mnist", images[idx])
                submitted = due
            time.sleep(0.001)
        # close() drains everything admitted; stats cover all requests
    st = srv.stats()
    ms = st.per_model["mnist"]
    return {
        "offered_inf_per_s": offered_inf_per_s,
        "duration_s": duration_s,
        "n_requests": st.n_requests,
        "achieved_inf_per_s": st.inf_per_s,
        "mean_batch": st.mean_batch,
        "mean_occupancy": st.mean_occupancy,
        "queue_high_water": st.queue_high_water,
        "p50_ms": ms.latency.p50_ms,
        "p95_ms": ms.latency.p95_ms,
        "p99_ms": ms.latency.p99_ms,
        "queue_p99_ms": ms.queue.p99_ms,
    }


def main(fast: bool = False, json_path: str | None = None,
         write_json: bool = True):
    """fast=True is the CI smoke slice (short phases, small batches).
    write_json=False (benchmarks.run) returns rows without touching the
    committed BENCH_serve.json trajectory file."""
    import sys

    # serving is a thread pipeline (clients -> dispatch -> completion);
    # the 5 ms default GIL switch interval lets any pure-Python stage
    # convoy the others for whole batch-times.  Standard server tuning.
    prev_switch = sys.getswitchinterval()
    sys.setswitchinterval(2e-4)
    try:
        return _main(fast, json_path, write_json)
    finally:
        sys.setswitchinterval(prev_switch)


def _main(fast: bool, json_path: str | None, write_json: bool):
    max_batch = 64 if fast else 256
    wait_us = 1000.0
    duration = 1.0 if fast else 4.0
    policy = BatchingPolicy(max_batch=max_batch, max_wait_us=wait_us,
                            max_inflight=4)

    folded = random_folded(PAPER_SIZES)
    # the serving deployment artifact: the server registers the SAME
    # object a checkpoint directory would reconstruct (deploy.Deployment)
    deployment = deploy(folded, ens_cfg=ensemble.EnsembleConfig(),
                        max_bucket=max_batch)
    pipe = deployment.pipeline()
    rng = np.random.default_rng(7)
    images = rng.choice([-1.0, 1.0], (1024, PAPER_SIZES[0])).astype(
        np.float32
    )

    raw_trials = [measure_raw(pipe, max_batch, duration)]
    plans = [
        mapping.plan_layer(n_out, n_in, 64)
        for n_in, n_out in zip(PAPER_SIZES[:-1], PAPER_SIZES[1:])
    ]
    silicon = mapping.model_inference_cost(
        plans, ensemble.EnsembleConfig().n_passes
    ).inferences_per_s

    print("# serve_load: section,point,inf_per_s,ratio_vs_raw,"
          "p50_ms,p95_ms,p99_ms")

    # -- closed loop: saturate, measure serving efficiency ------------
    # raw is re-measured around every load point and the MEDIAN used for
    # ratios: on a small shared host the attainable rate drifts by 2x
    # between minutes, so a single raw sample would make the efficiency
    # ratio a lottery — interleaving samples the same conditions the
    # engine ran under.
    closed = []
    points = [(1, max_batch, 3)] if fast else [(1, max_batch, 2),
                                               (1, max_batch, 3),
                                               (2, max_batch, 3)]
    for n_clients, window, depth in points:
        r = closed_loop(deployment, policy, n_clients, window, duration,
                        images, depth=depth)
        raw_trials.append(measure_raw(pipe, max_batch, duration))
        closed.append(r)
    raw = sorted(raw_trials,
                 key=lambda r: r["inf_per_s"])[len(raw_trials) // 2]
    print(f"raw,batch{raw['batch']},{raw['inf_per_s']:.0f},1.00,,,"
          f"  (median of {len(raw_trials)} interleaved trials)")
    for (n_clients, window, depth), r in zip(points, closed):
        r["depth"] = depth
        r["ratio_vs_raw"] = r["inf_per_s"] / raw["inf_per_s"]
        print(f"closed,{n_clients}x{window}d{depth},{r['inf_per_s']:.0f},"
              f"{r['ratio_vs_raw']:.3f},{r['p50_ms']:.2f},"
              f"{r['p95_ms']:.2f},{r['p99_ms']:.2f}")
    sat = max(closed, key=lambda r: r["inf_per_s"])

    # -- open loop: latency vs offered load ---------------------------
    fracs = (0.3, 0.7) if fast else (0.3, 0.6, 0.9)
    opened = []
    for frac in fracs:
        rate = frac * sat["inf_per_s"]
        r = open_loop(deployment, policy, rate, duration, images)
        r["offered_frac_of_saturation"] = frac
        opened.append(r)
        print(f"open,{frac:.1f}sat,{r['achieved_inf_per_s']:.0f},"
              f"{r['achieved_inf_per_s'] / raw['inf_per_s']:.3f},"
              f"{r['p50_ms']:.2f},{r['p95_ms']:.2f},{r['p99_ms']:.2f}")

    record = {
        "schema": "picbnn-bench-serve/v1",
        "model": {"layer_sizes": list(PAPER_SIZES),
                  "n_passes": ensemble.EnsembleConfig().n_passes},
        "policy": {"max_batch": max_batch, "max_wait_us": wait_us,
                   "max_inflight": policy.max_inflight},
        "pipeline_impl": pipe.impl,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "jax_version": jax.__version__,
        "fast": fast,
        "raw": raw,
        "raw_trials_inf_per_s": [t["inf_per_s"] for t in raw_trials],
        "silicon_equivalent_inf_per_s": silicon,
        "closed_loop": closed,
        "open_loop": opened,
        "saturation": {
            "inf_per_s": sat["inf_per_s"],
            "ratio_vs_raw": sat["ratio_vs_raw"],
            "vs_silicon_560k": sat["inf_per_s"] / silicon,
        },
    }
    print(f"# saturation: {sat['inf_per_s']:.0f} inf/s = "
          f"{sat['ratio_vs_raw']:.1%} of raw "
          f"({raw['inf_per_s']:.0f}); silicon Table-II equivalent "
          f"{silicon:.0f}")
    if write_json:
        out = Path(json_path) if json_path else REPO_ROOT / "BENCH_serve.json"
        out.write_text(json.dumps(record, indent=2) + "\n")
        print(f"# wrote {out}")
    return {"raw": raw, "closed_loop": closed, "open_loop": opened,
            "saturation": record["saturation"]}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--fast", action="store_true", dest="fast",
                    help="short CI slice (small batches, 1s phases)")
    ap.add_argument("--json", default=None, help="output path override")
    args = ap.parse_args()
    main(fast=args.fast, json_path=args.json)

"""§Roofline table: read results/dryrun/*.json and print per-cell terms.

Columns:
  arch, shape, mesh, status, microbatches,
  compute_s, memory_s, collective_s, bottleneck,
  model_tflops (global), hlo_tflops (global), useful_ratio,
  roofline_fraction, peak_gib_per_dev
"""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(mesh_tag: str = "pod"):
    cells = []
    for p in sorted(RESULTS.glob(f"*__{mesh_tag}.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def row(rec: dict) -> str:
    a, s = rec["arch"], rec["shape"]
    tag = "multipod" if rec.get("multi_pod") else "pod"
    if rec.get("status") == "skipped":
        return f"roofline,{a},{s},{tag},skipped,,,,,,,,,"
    if rec.get("status") != "ok":
        return f"roofline,{a},{s},{tag},ERROR,,,,,,,,,"
    r = rec["roofline"]
    m = rec["memory_analysis"]
    return (
        f"roofline,{a},{s},{tag},ok,{rec.get('microbatches', '')},"
        f"{r['compute_s']:.4g},{r['memory_s']:.4g},{r['collective_s']:.4g},"
        f"{r['bottleneck']},{r['model_flops_global']/1e12:.4g},"
        f"{r['hlo_flops_global']/1e12:.4g},{r['useful_ratio']:.3f},"
        f"{r['roofline_fraction']:.3f},{m['peak_estimate_gib']:.2f}"
    )


def main():
    print(
        "# roofline,arch,shape,mesh,status,microbatches,compute_s,memory_s,"
        "collective_s,bottleneck,model_tflops,hlo_tflops,useful_ratio,"
        "roofline_fraction,peak_gib_per_dev"
    )
    if not RESULTS.exists():
        print("# no dry-run results found — run python -m repro.launch.dryrun")
        return []
    rows = []
    for tag in ("pod", "multipod"):
        for rec in load_cells(tag):
            line = row(rec)
            rows.append(line)
            print(line)
    return rows


if __name__ == "__main__":
    main()

"""Kernel microbenchmarks.

CPU wall times cover the interpret-mode kernels (semantics only); the
TPU-relevant numbers are the arithmetic-intensity / bandwidth derivations
printed alongside: the packed XNOR-popcount GEMM moves 16x fewer HBM
bytes than a bf16 GEMM of the same logical shape, which is the paper's
"weights stay in the array" property translated to a memory-roofline win.

Output CSV: name,us_per_call,derived
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize
from repro.kernels import ops, ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # compile / warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def bench_binary_gemm(m=256, n=256, k=4096):
    rng = np.random.default_rng(0)
    xp = binarize.pack_bits(
        jnp.asarray(rng.integers(0, 2, (m, k)).astype(np.uint8)))
    wp = binarize.pack_bits(
        jnp.asarray(rng.integers(0, 2, (n, k)).astype(np.uint8)))
    us_kernel = _time(
        lambda a, b: ops.binary_gemm_hd(a, b, bm=128, bn=128, chunk=8),
        xp, wp, reps=1,
    )
    us_ref = _time(ref.binary_gemm_hd_ref, xp, wp)
    # TPU projection: HBM bytes = packed operands + int32 out
    bytes_packed = (m + n) * (k // 8) + m * n * 4
    bytes_bf16 = (m + n) * k * 2 + m * n * 4
    t_mem_packed = bytes_packed / HBM_BW
    t_mem_bf16 = bytes_bf16 / HBM_BW
    flops = 2 * m * n * k  # xnor+acc counted as 2 ops
    rows = [
        ("binary_gemm_pallas_interp", us_kernel,
         f"{m}x{n}x{k};exact-vs-ref"),
        ("binary_gemm_ref_jnp", us_ref, f"{m}x{n}x{k}"),
        ("binary_gemm_tpu_mem_bound_us", t_mem_packed * 1e6,
         f"packed:{bytes_packed}B"),
        ("bf16_gemm_tpu_mem_bound_us", t_mem_bf16 * 1e6,
         f"bf16:{bytes_bf16}B;packed_speedup={t_mem_bf16/t_mem_packed:.1f}x"),
    ]
    return rows


def bench_cam_vote(b=512, c=2048, k=4160, p=33):
    rng = np.random.default_rng(1)
    q = binarize.pack_bits(
        jnp.asarray(rng.integers(0, 2, (b, k)).astype(np.uint8)))
    rows_ = binarize.pack_bits(
        jnp.asarray(rng.integers(0, 2, (c, k)).astype(np.uint8)))
    thr = jnp.arange(p, dtype=jnp.int32) * (k // p)
    us_ref = _time(ref.cam_vote_ref, q, rows_, thr)
    # fused vs faithful: the fused sweep reads the array once instead of
    # p times — the beyond-paper optimization quantified
    bytes_once = (b + c) * (k // 8) + b * c * 4
    rows = [
        ("cam_vote_ref_jnp", us_ref, f"{b}x{c}x{k}x{p}"),
        ("cam_vote_fused_array_reads", 1.0,
         f"vs {p} reads faithful: {p}x fewer"),
        ("cam_vote_tpu_mem_bound_us", bytes_once / HBM_BW * 1e6,
         f"{bytes_once}B"),
    ]
    return rows


def main(fast: bool = False):
    print("# kernel microbench: name,us_per_call,derived")
    rows = bench_binary_gemm(*( (64, 64, 512) if fast else (256, 256, 4096)))
    rows += bench_cam_vote(*( (32, 64, 512, 9) if fast else (512, 2048, 4160, 33)))
    for r in rows:
        print(f"kern,{r[0]},{r[1]:.2f},{r[2]}")
    return rows


if __name__ == "__main__":
    main()

"""Deprecation lint (wired into scripts/smoke.sh).

The ISSUE-5 API redesign collapsed the eight-way `CompiledPipeline`
entry-point family into `run(x, InferenceSpec(...))`; the old methods
survive ONLY as deprecated shims inside `src/repro/pipeline.py` (one
release).  This gate keeps them from creeping back: it fails if any
non-shim code under `src/` or `benchmarks/` (or `examples/`) still
calls a legacy entry method.

Mechanics: every ``*.py`` file is AST-scanned for *attribute calls*
named like a legacy entry (``something.votes(...)``, ``x.cum_votes(...)``
...).  Module-level function calls (e.g. ``ensemble.predict`` does not
exist; ``predict(...)`` as a bare name) are not flagged — the lint
targets the pipeline method surface.  The shim module itself and the
test suite (which intentionally exercises the shims as the
pre-redesign bit-exactness oracle) are exempt.

Run:  python scripts/check_deprecated.py
Exit status 0 on success; prints every violation otherwise.
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]

#: the retired entry-point family (see repro.spec.legacy_entry_spec)
LEGACY_METHODS = frozenset({
    "votes", "votes_packed", "votes_mc", "votes_each", "votes_mc_each",
    "votes_mc_each_sum", "cum_votes", "predict", "predict_each",
})

#: directories held to the no-legacy-calls bar
SCAN_DIRS = ("src", "benchmarks", "examples")

#: the one place the shims are allowed to live
EXEMPT = {Path("src/repro/pipeline.py")}

#: attribute calls that are NOT pipeline entry points (other objects
#: legitimately expose a same-named method)
ALLOWED_RECEIVERS = {
    # e.g. sklearn-style `model.predict(...)` on an LM engine would go
    # here; none exist today — extend deliberately, with a comment.
}


def _violations(path: Path) -> list[str]:
    """Legacy pipeline-method attribute calls in one file."""
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:  # a broken file is its own violation
        return [f"{path}: syntax error: {e}"]
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if not (isinstance(fn, ast.Attribute) and fn.attr in LEGACY_METHODS):
            continue
        recv = ast.unparse(fn.value) if hasattr(ast, "unparse") else "?"
        if (recv, fn.attr) in ALLOWED_RECEIVERS:
            continue
        out.append(
            f"{path.relative_to(REPO_ROOT)}:{node.lineno}: legacy entry "
            f"`{recv}.{fn.attr}(...)` — use run(x, InferenceSpec(...)); "
            "see repro.spec.legacy_entry_spec / README migration table"
        )
    return out


def main() -> int:
    """Scan SCAN_DIRS; print violations; return a process exit status."""
    failures: list[str] = []
    n_files = 0
    for d in SCAN_DIRS:
        for path in sorted((REPO_ROOT / d).rglob("*.py")):
            if path.relative_to(REPO_ROOT) in EXEMPT:
                continue
            n_files += 1
            failures += _violations(path)
    if failures:
        print(f"check_deprecated: {len(failures)} legacy entry call(s)")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"check_deprecated OK: {n_files} files scanned, no legacy "
          "pipeline entry calls outside the shims")
    return 0


if __name__ == "__main__":
    sys.exit(main())

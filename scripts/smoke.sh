#!/usr/bin/env bash
# CI smoke entry point: full test suite + fast machine-readable benchmarks.
#
# Usage: scripts/smoke.sh [output.json]
#   output.json — where the benchmark JSON lands (default: results/smoke_bench.json)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
OUT="${1:-results/smoke_bench.json}"
mkdir -p "$(dirname "$OUT")"

python -m pytest -q
python scripts/check_docs.py
python scripts/check_deprecated.py
python -m benchmarks.run --fast --only kern,table2,conv,noise,serve --json "$OUT"

echo "smoke OK -> $OUT"

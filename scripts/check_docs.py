"""Documentation gate (wired into scripts/smoke.sh).

Two checks, both fast and dependency-free:

1. **Docstring audit** — every public module / class / function /
   method of the public API surface (the modules listed in
   ``API_MODULES``) carries a docstring.  "Public" = name does not start
   with an underscore and the object is *defined* in that module (re-
   exports are the defining module's responsibility).  This is the
   enforcement half of the PR-4 docstring audit: shapes, packed-domain
   conventions, and determinism guarantees live in docstrings, so a
   missing docstring is a missing contract.

2. **Doc snippet import-check** — every ```python fenced block in
   README.md, DESIGN.md, and docs/*.md must (a) parse and (b) have its
   top-level ``import`` / ``from`` statements actually execute, so code
   snippets cannot silently rot as modules move.  Snippet bodies are NOT
   executed (they may train models / write files); imports are the part
   that goes stale.

Run:  PYTHONPATH=src python scripts/check_docs.py
Exit status 0 on success; prints every violation otherwise.
"""

from __future__ import annotations

import ast
import inspect
import importlib
import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]

#: the public API surface held to the docstring bar
API_MODULES = [
    "repro.pipeline",
    "repro.spec",
    "repro.deploy",
    "repro.serve.picbnn",
    "repro.serve.scheduler",
    "repro.core.physics",
    "repro.core.binarize",
    "repro.core.bnn",
    "repro.core.convnet",
    "repro.core.ensemble",
    "repro.core.mapping",
    "repro.kernels.fused_mlp",
    "repro.kernels.fused_conv",
    "repro.kernels.ref",
    "repro.configs.paper_mlp",
    "repro.configs.paper_cnn",
    "repro.data.synthetic",
]

#: documentation files whose ```python blocks are import-checked
DOC_FILES = ["README.md", "DESIGN.md"]


def _missing_docstrings(mod) -> list[str]:
    """Names in `mod` (module, public defs, public methods) lacking docs."""
    bad = []
    if not (mod.__doc__ or "").strip():
        bad.append(f"{mod.__name__} (module)")
    for name, obj in vars(mod).items():
        if name.startswith("_"):
            continue
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue
        if getattr(obj, "__module__", None) != mod.__name__:
            continue  # re-export: audited at its defining module
        if not (inspect.getdoc(obj) or "").strip():
            bad.append(f"{mod.__name__}.{name}")
        if inspect.isclass(obj):
            for mname, meth in vars(obj).items():
                if mname.startswith("_"):
                    continue
                fn = meth
                if isinstance(meth, (staticmethod, classmethod)):
                    fn = meth.__func__
                elif isinstance(meth, property):
                    fn = meth.fget
                if not inspect.isfunction(fn):
                    continue
                if not (inspect.getdoc(fn) or "").strip():
                    bad.append(f"{mod.__name__}.{name}.{mname}")
    return bad


_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _snippet_errors(path: Path) -> list[str]:
    """Syntax + import errors in a doc file's ```python blocks."""
    errors = []
    text = path.read_text()
    for i, block in enumerate(_FENCE.findall(text), 1):
        where = f"{path.name} python block #{i}"
        try:
            tree = ast.parse(block)
        except SyntaxError as e:
            errors.append(f"{where}: syntax error: {e}")
            continue
        imports = [
            node for node in tree.body
            if isinstance(node, (ast.Import, ast.ImportFrom))
        ]
        for node in imports:
            src = ast.unparse(node)
            try:
                exec(compile(ast.Module([node], []), where, "exec"), {})
            except Exception as e:
                errors.append(f"{where}: `{src}` failed: {e}")
    return errors


def main() -> int:
    """Run both gates; print violations; return a process exit status."""
    failures = []
    for name in API_MODULES:
        try:
            mod = importlib.import_module(name)
        except Exception as e:
            failures.append(f"cannot import {name}: {e}")
            continue
        failures += [f"missing docstring: {n}"
                     for n in _missing_docstrings(mod)]

    doc_paths = [REPO_ROOT / f for f in DOC_FILES]
    doc_paths += sorted((REPO_ROOT / "docs").glob("*.md"))
    n_files = 0
    for path in doc_paths:
        if not path.exists():
            failures.append(f"missing documentation file: {path.name}")
            continue
        n_files += 1
        failures += _snippet_errors(path)

    if failures:
        print(f"check_docs: {len(failures)} violation(s)")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"check_docs OK: {len(API_MODULES)} modules audited, "
          f"{n_files} doc files snippet-checked")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Deployment artifact: the persistable bundle behind one compiled BNN.

The paper's deployment story is a single artifact — a folded, bit-packed
BNN written once into the CAM, then queried under one search contract
(Algorithm 1 with knob-configured noise).  :class:`Deployment` is that
artifact for this repo: folded layers + binary input encoding +
`EnsembleConfig` + `NoiseModel`/`AnalogParams` + compile options, with

  * one constructor for MLP and CNN deployments alike
    (:func:`deploy` — takes folded layers, or trained params + config
    and folds them);
  * lazy compilation: `.pipeline()` builds the fused
    `pipeline.CompiledPipeline` on first use, which itself compiles one
    program per `repro.spec.InferenceSpec` on demand;
  * persistence through the existing `checkpoint/ckpt.py` machinery:
    `save(dir)` writes `deployment.json` (the declarative config +
    layer topology) plus an atomic checkpoint step of BIT-PACKED
    weights; `Deployment.load(dir)` reconstructs a deployment whose
    `run(x, spec)` is bit-identical to the original
    (tests/test_deploy.py proves this on all three bank configurations
    and the CNN configs, noiseless and per-request silicon).

Serving integration: `serve.picbnn.PicBnnServer.register` accepts a
live `Deployment` or a saved deployment directory, so servers register
models straight from disk.

On-disk layout::

    <dir>/deployment.json       declarative config (schema
                                picbnn-deployment/v1): layer topology,
                                ensemble/noise/encoding/compile options
    <dir>/step_00000000/        ckpt.save output — manifest.json + one
                                .npy per leaf: packed uint32 weight
                                words + int32 C_j constants per layer

The weight files hold `pack_bits`-packed rows (32 weights per uint32
word, little-endian) — 32x smaller than the ±1 int8 form and exactly
what the CAM write would consume.  Unpacking on load is bit-exact by
construction (weights are ±1, so `w > 0` is invertible).
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional, Sequence, Union

import jax
import numpy as np

from repro import pipeline as _pipeline
from repro.checkpoint import ckpt
from repro.core import binarize, bnn, convnet
from repro.core.binarize import InputEncoding
from repro.core.bnn import FoldedLayer, MLPConfig
from repro.core.convnet import CNNConfig, FoldedConvLayer
from repro.core.device_model import AnalogParams, NoiseModel
from repro.core.ensemble import EnsembleConfig
from repro.spec import InferenceSpec

SCHEMA = "picbnn-deployment/v1"

#: compile_pipeline options a Deployment may carry (everything except
#: the model/physics inputs, which are first-class Deployment fields)
COMPILE_OPTIONS = ("impl", "bq", "chunk", "min_bucket", "max_bucket",
                   "interpret", "donate")


def _np_unpack_bits(words: np.ndarray, n_bits: int) -> np.ndarray:
    """NumPy twin of binarize.unpack_bits (little-endian uint32 words)."""
    shifts = np.arange(32, dtype=np.uint32)
    bits = (words[..., None] >> shifts) & np.uint32(1)
    return bits.reshape(*words.shape[:-1], -1)[..., :n_bits].astype(np.uint8)


def _pack_rows(weights_pm1: np.ndarray) -> np.ndarray:
    """±1 weight rows (any trailing shape) -> packed uint32 words."""
    rows = np.asarray(weights_pm1).reshape(weights_pm1.shape[0], -1)
    return binarize.np_pack_bits((rows > 0).astype(np.uint8))


def _unpack_rows(words: np.ndarray, shape: Sequence[int]) -> np.ndarray:
    """Inverse of `_pack_rows`: packed words -> ±1 int8 of `shape`."""
    shape = tuple(int(s) for s in shape)
    n_bits = int(np.prod(shape[1:]))
    bits = _np_unpack_bits(np.asarray(words), n_bits)
    return (bits.astype(np.int8) * 2 - 1).reshape(shape)


@dataclasses.dataclass
class Deployment:
    """A persistable deployed BNN: model + physics + compile config.

    Construct with :func:`deploy` (or :meth:`load`); treat as immutable.
    `pipeline()` compiles lazily and caches; `run()` / `warmup()`
    delegate to it, so a Deployment is used exactly like a
    `CompiledPipeline` — plus `save()`.
    """

    folded: tuple  # FoldedConvLayer prefix + FoldedLayer tail
    ens_cfg: EnsembleConfig
    noise: Optional[NoiseModel] = None
    params: Optional[AnalogParams] = None
    image_side: Optional[int] = None
    image_encoding: Optional[InputEncoding] = None
    compile_options: dict = dataclasses.field(default_factory=dict)
    _pipe: Optional[_pipeline.CompiledPipeline] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def __post_init__(self):
        unknown = set(self.compile_options) - set(COMPILE_OPTIONS)
        if unknown:
            raise ValueError(
                f"unknown compile options {sorted(unknown)}; "
                f"known: {COMPILE_OPTIONS}"
            )

    # ------------------------------------------------------------------
    # model topology
    # ------------------------------------------------------------------
    @property
    def conv_layers(self) -> tuple:
        """The FoldedConvLayer prefix (empty for MLP deployments)."""
        return tuple(l for l in self.folded
                     if isinstance(l, FoldedConvLayer))

    @property
    def layer_sizes(self) -> Optional[tuple[int, ...]]:
        """(n_in, ..., n_classes) for pure-MLP deployments, else None.

        Serving uses this to derive the Table-II silicon-equivalent
        throughput without the caller restating the topology.
        """
        if self.conv_layers:
            return None
        fc = [l for l in self.folded]
        return (int(fc[0].n_in),) + tuple(int(l.n_out) for l in fc)

    # ------------------------------------------------------------------
    # lazy compilation + execution
    # ------------------------------------------------------------------
    def pipeline(self) -> _pipeline.CompiledPipeline:
        """The compiled pipeline (built on first call, then cached).

        Program compilation is itself lazy per `InferenceSpec` — a
        deployment only pays XLA compile time for the specs it actually
        runs (or warms).
        """
        if self._pipe is None:
            kw = dict(self.compile_options)
            if self.image_side is not None:
                kw["image_side"] = self.image_side
                kw["image_encoding"] = self.image_encoding
            self._pipe = _pipeline.compile_pipeline(
                list(self.folded), self.ens_cfg,
                noise=self.noise, params=self.params, **kw
            )
        return self._pipe

    def run(self, x: jax.Array, spec: InferenceSpec, *,
            key: Optional[jax.Array] = None,
            keys: Optional[jax.Array] = None) -> jax.Array:
        """`CompiledPipeline.run` on the (lazily compiled) pipeline."""
        return self.pipeline().run(x, spec, key=key, keys=keys)

    def warmup(self, max_batch: int, **kw):
        """`CompiledPipeline.warmup` on the (lazily compiled) pipeline."""
        return self.pipeline().warmup(max_batch, **kw)

    # ------------------------------------------------------------------
    # persistence
    # ------------------------------------------------------------------
    def save(self, root: Union[str, Path]) -> Path:
        """Persist to `root/`: packed-weight checkpoint + manifest.

        The weight arrays go through `checkpoint.ckpt.save` (atomic
        tmp-dir + rename), then `deployment.json` is written — its
        presence marks a complete artifact.  Returns `root` as a Path.
        """
        root = Path(root)
        tree = {"layers": []}
        layers_meta = []
        for layer in self.folded:
            if isinstance(layer, FoldedConvLayer):
                layers_meta.append({
                    "kind": "conv",
                    "shape": list(layer.weights_pm1.shape),
                    "stride": int(layer.stride),
                })
            else:
                layers_meta.append({
                    "kind": "fc",
                    "shape": list(layer.weights_pm1.shape),
                })
            tree["layers"].append({
                "w": _pack_rows(layer.weights_pm1),
                "c": np.asarray(layer.c, np.int32),
            })
        ckpt.save(root, step=0, tree=tree)
        manifest = {
            "schema": SCHEMA,
            "layers": layers_meta,
            "ens_cfg": {
                "thresholds": [int(t) for t in self.ens_cfg.thresholds],
                "bias_cells": int(self.ens_cfg.bias_cells),
                "mode": self.ens_cfg.mode,
                "calibrated": bool(self.ens_cfg.calibrated),
                # the pipeline itself ignores ens_cfg.noise (physics come
                # from Deployment.noise), but load(save(d)).ens_cfg must
                # equal d.ens_cfg — faithful round trip, field by field
                "noise": dataclasses.asdict(self.ens_cfg.noise),
            },
            "noise": (None if self.noise is None
                      else dataclasses.asdict(self.noise)),
            "analog_params": (None if self.params is None
                              else dataclasses.asdict(self.params)),
            "image_side": self.image_side,
            "image_encoding": (None if self.image_encoding is None else {
                "kind": self.image_encoding.kind,
                "width": int(self.image_encoding.width),
            }),
            "compile_options": self.compile_options,
        }
        (root / "deployment.json").write_text(json.dumps(manifest, indent=1))
        return root

    @classmethod
    def load(cls, root: Union[str, Path]) -> "Deployment":
        """Reconstruct a Deployment saved by :meth:`save`.

        Bit-exactness contract: `load(d.save(p)).run(x, spec)` equals
        `d.run(x, spec)` bit-for-bit for every spec (the weights are ±1,
        so packing is invertible; every config field round-trips through
        JSON exactly).
        """
        root = Path(root)
        mf_path = root / "deployment.json"
        if not mf_path.exists():
            raise FileNotFoundError(
                f"{root} is not a deployment directory (no deployment.json)"
            )
        mf = json.loads(mf_path.read_text())
        if mf.get("schema") != SCHEMA:
            raise ValueError(
                f"unsupported deployment schema {mf.get('schema')!r} "
                f"(expected {SCHEMA})"
            )
        template = {"layers": []}
        for lm in mf["layers"]:
            shape = lm["shape"]
            n_rows = int(shape[0])
            n_bits = int(np.prod(shape[1:]))
            template["layers"].append({
                "w": jax.ShapeDtypeStruct(
                    (n_rows, binarize.packed_width(n_bits)), np.uint32
                ),
                "c": jax.ShapeDtypeStruct((n_rows,), np.int32),
            })
        tree, _step = ckpt.restore(root, None, template)
        folded = []
        for lm, leaf in zip(mf["layers"], tree["layers"]):
            w = _unpack_rows(np.asarray(leaf["w"]), lm["shape"])
            c = np.asarray(leaf["c"], np.int64)
            if lm["kind"] == "conv":
                folded.append(FoldedConvLayer(
                    weights_pm1=w, c=c, stride=int(lm["stride"])
                ))
            else:
                folded.append(FoldedLayer(weights_pm1=w, c=c))
        ecd = mf["ens_cfg"]
        enc = mf["image_encoding"]
        return cls(
            folded=tuple(folded),
            ens_cfg=EnsembleConfig(
                thresholds=tuple(ecd["thresholds"]),
                bias_cells=ecd["bias_cells"],
                mode=ecd["mode"],
                calibrated=ecd["calibrated"],
                noise=NoiseModel(**ecd["noise"]),
            ),
            noise=(None if mf["noise"] is None
                   else NoiseModel(**mf["noise"])),
            params=(None if mf["analog_params"] is None
                    else AnalogParams(**mf["analog_params"])),
            image_side=mf["image_side"],
            image_encoding=(None if enc is None
                            else InputEncoding(enc["kind"], enc["width"])),
            compile_options=dict(mf["compile_options"]),
        )


def is_deployment_dir(path: Union[str, Path]) -> bool:
    """True when `path` holds a saved Deployment (has deployment.json)."""
    return (Path(path) / "deployment.json").exists()


def deploy(
    model,
    *,
    config: Union[MLPConfig, CNNConfig, None] = None,
    ens_cfg: Optional[EnsembleConfig] = None,
    noise: Optional[NoiseModel] = None,
    params: Optional[AnalogParams] = None,
    image_side: Optional[int] = None,
    image_encoding: Optional[InputEncoding] = None,
    **compile_options,
) -> Deployment:
    """Build a `Deployment` from a model — MLP and CNN configs alike.

    model : either already-folded layers (`bnn.fold` / `convnet.fold_cnn`
        / `convnet.random_folded_cnn` output: an optional
        `FoldedConvLayer` prefix + `FoldedLayer` tail), or a TRAINED
        params dict — then `config` is required and the fold runs here
        (`bnn.fold` for `MLPConfig`, `convnet.fold_cnn` for `CNNConfig`).
    config : optional `MLPConfig` | `CNNConfig`; supplies the defaults a
        hand-rolled call would restate — `bias_cells` for the ensemble
        config, and (CNN) the image side + binary input encoding.
    ens_cfg / noise / params / image_side / image_encoding : as
        `pipeline.compile_pipeline`; explicit arguments win over
        config-derived defaults.
    compile_options : forwarded to `compile_pipeline` at (lazy) compile
        time — one of `deploy.COMPILE_OPTIONS` (impl, bq, chunk,
        min_bucket, max_bucket, interpret, donate).

    >>> d = deploy(bnn.fold(params, cfg), config=cfg, noise=SILICON)
    >>> d.run(x, InferenceSpec(noise="per_request"), keys=keys)
    >>> d.save("ckpts/mnist")       # serve later:
    >>> server.register("mnist", "ckpts/mnist")
    """
    if isinstance(model, dict):
        if isinstance(config, CNNConfig):
            folded = convnet.fold_cnn(model, config)
        elif isinstance(config, MLPConfig):
            folded = bnn.fold(model, config)
        else:
            raise ValueError(
                "deploy(params_dict) needs config=MLPConfig|CNNConfig "
                "to fold the trained parameters"
            )
    else:
        folded = list(model)
    if isinstance(config, CNNConfig):
        image_side = config.side if image_side is None else image_side
        image_encoding = (config.encoding if image_encoding is None
                          else image_encoding)
    if ens_cfg is None:
        bias = getattr(config, "bias_cells", None)
        ens_cfg = (EnsembleConfig(bias_cells=bias) if bias is not None
                   else EnsembleConfig())
    return Deployment(
        folded=tuple(folded),
        ens_cfg=ens_cfg,
        noise=noise,
        params=params,
        image_side=image_side,
        image_encoding=image_encoding,
        compile_options=compile_options,
    )

"""repro: PiC-BNN (Processing-in-CAM BNN accelerator) reproduced as a
production-grade multi-pod JAX framework.

Layers:
  repro.core      -- the paper's contribution (binarization, CAM, ensemble)
  repro.kernels   -- Pallas TPU kernels for the paper's compute hot spots
  repro.models    -- LM substrate (dense / MoE / SSM / hybrid backbones)
  repro.sharding  -- logical-axis -> mesh partitioning rules
  repro.configs   -- assigned architectures + the paper's own models
  repro.train     -- optimizer, train step, gradient compression
  repro.serve     -- prefill/decode steps + batched serving engine
  repro.data      -- data pipelines (synthetic + memmap token streams)
  repro.checkpoint-- atomic/async checkpointing with elastic restore
  repro.ft        -- fault tolerance: supervisor, straggler monitor
  repro.launch    -- production mesh, multi-pod dry-run, roofline analysis
"""

__version__ = "1.0.0"

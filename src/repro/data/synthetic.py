"""Synthetic drop-in datasets for the paper's evaluation.

The paper evaluates on MNIST (10 classes, 28x28) and the Kaggle Hand
Gesture dataset (20 classes, 64x64).  Neither ships in this offline
container, so we generate *procedural* datasets with identical shapes and
class counts: per-class stroke-glyph templates rendered with random
shift / rotation-ish shear / pixel noise.  Every relative claim of the
paper (BNN vs fp32 baseline, accuracy vs pass count, noise robustness) is
evaluated on the same synthetic data for both pipelines, so comparisons
remain meaningful; absolute accuracies are reported against OUR software
baseline (DESIGN.md §Assumptions).

Deterministic by seed; images in [0,1]; `binarize_images` maps to the
+-1 domain the CAM consumes.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_classes: int
    side: int  # image side (square)

    @property
    def n_pixels(self) -> int:
        """Flattened image length (side^2)."""
        return self.side * self.side


MNIST_LIKE = DatasetSpec("mnist-like", 10, 28)
HG_LIKE = DatasetSpec("hg-like", 20, 64)


def _glyph_template(rng: np.random.Generator, side: int) -> np.ndarray:
    """A class template: a few random thick strokes on a side x side grid."""
    if side < 8:
        raise ValueError(f"glyph side must be >= 8, got {side}")
    img = np.zeros((side, side), np.float32)
    n_strokes = rng.integers(2, 5)
    for _ in range(n_strokes):
        x0, y0 = rng.integers(2, side - 2, 2)
        angle = rng.uniform(0, 2 * np.pi)
        length = rng.integers(side // 3, side - 4)
        thick = max(1, side // 14)
        for t in range(length):
            x = int(x0 + t * np.cos(angle))
            y = int(y0 + t * np.sin(angle))
            if 0 <= x < side and 0 <= y < side:
                # numpy clips the upper bound; the lower is clamped so a
                # near-edge stroke thickens inward instead of wrapping
                img[
                    max(x - thick, 0) : x + thick, max(y - thick, 0) : y + thick
                ] = 1.0
    return img


def _shift_fill(a: np.ndarray, shift: int, axis: int) -> np.ndarray:
    """np.roll with zero fill: pixels shifted past the edge DROP.

    np.roll wraps content to the opposite edge — at 28x28 the glyphs sit
    far enough from the border that this never showed, but the 64x64 HG
    shape draws strokes up to `side - 4` long, and shear offsets grow
    with the row index, so reusing the generator at CNN input widths
    silently teleported stroke pixels across the image (label noise with
    no visual justification).  Augmentation must lose, not wrap, what
    leaves the frame.
    """
    if shift == 0:
        return a
    out = np.zeros_like(a)
    src = [slice(None)] * a.ndim
    dst = [slice(None)] * a.ndim
    if shift > 0:
        dst[axis], src[axis] = slice(shift, None), slice(None, -shift)
    else:
        dst[axis], src[axis] = slice(None, shift), slice(-shift, None)
    out[tuple(dst)] = a[tuple(src)]
    return out


def _augment(
    rng: np.random.Generator, template: np.ndarray, noise: float
) -> np.ndarray:
    side = template.shape[0]
    dx, dy = rng.integers(-2, 3, 2)
    img = _shift_fill(_shift_fill(template, int(dx), 0), int(dy), 1)
    # shear-ish distortion: per-row shift (zero-filled, no wrap-around)
    shear = rng.integers(-1, 2)
    if shear:
        img = img.copy()
        for r in range(side):
            img[r] = _shift_fill(img[r], (r * shear) // max(side // 4, 1), 0)
    img = img + rng.normal(0, noise, img.shape).astype(np.float32)
    flip = rng.random(img.shape) < noise * 0.15
    img = np.where(flip, 1.0 - img, img)
    return np.clip(img, 0.0, 1.0)


def make_dataset(
    spec: DatasetSpec,
    n_train: int = 8000,
    n_test: int = 2000,
    noise: float = 0.15,
    seed: int = 0,
):
    """Returns (train_x, train_y, test_x, test_y); x in [0,1] [N, side^2]."""
    rng = np.random.default_rng(seed)
    templates = [
        _glyph_template(rng, spec.side) for _ in range(spec.n_classes)
    ]
    def gen(n):
        xs = np.empty((n, spec.n_pixels), np.float32)
        ys = np.empty((n,), np.int64)
        for i in range(n):
            c = int(rng.integers(spec.n_classes))
            xs[i] = _augment(rng, templates[c], noise).reshape(-1)
            ys[i] = c
        return xs, ys

    train_x, train_y = gen(n_train)
    test_x, test_y = gen(n_test)
    return train_x, train_y, test_x, test_y


def binarize_images(x: np.ndarray, threshold: float = 0.5) -> np.ndarray:
    """[0,1] pixels -> +-1 (the end-to-end-binary input coding)."""
    return np.where(x >= threshold, 1.0, -1.0).astype(np.float32)

"""Token pipelines for LM training: synthetic streams and memmap files.

Production layout: each host reads its own shard of a flat uint32 token
file (memmap, zero-copy) with a stride equal to the host count — the
per-host batch is then device_put against the global batch sharding so
jax assembles the global array without cross-host traffic (the standard
multi-host input pattern).  On this single-host container the same code
paths run with host_count=1; multi-host identity is covered by unit tests
over the index math.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int  # global batch (sequences)
    seq_len: int
    vocab_size: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1


def synthetic_stream(cfg: DataConfig) -> Iterator[dict]:
    """Zipf-distributed random tokens with a causal LM (shift) target.

    Deterministic per (seed, host_index, step): restart-safe — resuming at
    step k regenerates the identical batch (checkpoint/restart tests rely
    on this property).
    """
    assert cfg.batch % cfg.host_count == 0
    per_host = cfg.batch // cfg.host_count
    step = 0
    while True:
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + cfg.host_index
        )
        z = rng.zipf(1.3, size=(per_host, cfg.seq_len + 1))
        toks = (z % (cfg.vocab_size - 1)).astype(np.int32) + 1
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        step += 1


def write_token_file(path: Path, tokens: np.ndarray):
    tokens.astype(np.uint32).tofile(path)


def memmap_stream(
    path: Path, cfg: DataConfig, start_step: int = 0
) -> Iterator[dict]:
    """Strided reads over a flat uint32 token file.

    Host h reads sequences [h, h + H, h + 2H, ...] of each global batch —
    host-disjoint and deterministic, so elastic restarts with a different
    host count re-partition cleanly.
    """
    data = np.memmap(path, dtype=np.uint32, mode="r")
    seq = cfg.seq_len + 1
    n_seqs = len(data) // seq
    per_host = cfg.batch // cfg.host_count
    step = start_step
    while True:
        base = (step * cfg.batch) % max(n_seqs - cfg.batch, 1)
        idx = base + cfg.host_index + cfg.host_count * np.arange(per_host)
        idx = idx % n_seqs
        block = np.stack([data[i * seq : (i + 1) * seq] for i in idx])
        block = block.astype(np.int32)
        yield {"tokens": block[:, :-1], "labels": block[:, 1:]}
        step += 1


def embeds_stream(cfg: DataConfig, d_model: int) -> Iterator[dict]:
    """Frontend-stub stream for embeds-input archs (vlm/audio): random
    frame/patch embeddings + token labels."""
    per_host = cfg.batch // cfg.host_count
    step = 0
    while True:
        rng = np.random.default_rng(cfg.seed + 7 * step + cfg.host_index)
        yield {
            "embeds": rng.normal(
                0, 1, (per_host, cfg.seq_len, d_model)
            ).astype(np.float32),
            "labels": rng.integers(
                0, cfg.vocab_size, (per_host, cfg.seq_len)
            ).astype(np.int32),
        }
        step += 1

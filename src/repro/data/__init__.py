"""Data pipelines: synthetic image datasets (paper eval), token streams
(LM substrate), frontend-stub embedding streams (vlm/audio archs)."""

from repro.data.synthetic import (  # noqa: F401
    HG_LIKE,
    MNIST_LIKE,
    DatasetSpec,
    binarize_images,
    make_dataset,
)
from repro.data.tokens import DataConfig, memmap_stream, synthetic_stream  # noqa: F401

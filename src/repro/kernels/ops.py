"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python with identical semantics to the compiled TPU path; on
TPU they compile to Mosaic.  `interpret` is resolved once from the backend
unless overridden.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import binary_gemm as _bg
from repro.kernels import cam_search as _cs


@functools.lru_cache(maxsize=1)
def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def binary_gemm_hd(x_packed, w_packed, *, interpret: bool | None = None, **kw):
    """Pairwise Hamming distances between packed rows ([M,Kw],[N,Kw]->[M,N])."""
    if interpret is None:
        interpret = _default_interpret()
    return _bg.binary_gemm_hd(x_packed, w_packed, interpret=interpret, **kw)


def binary_gemm_dot(
    x_packed, w_packed, n_bits: int, *, interpret: bool | None = None, **kw
):
    """XNOR-popcount dot products in the +-1 domain: n_bits - 2*HD."""
    hd = binary_gemm_hd(x_packed, w_packed, interpret=interpret, **kw)
    return n_bits - 2 * hd


def cam_vote(q_packed, rows_packed, thresholds, *, interpret=None, **kw):
    """Fused Algorithm-1 vote counts ([B,Kw],[C,Kw],[P] -> [B,C] int32)."""
    if interpret is None:
        interpret = _default_interpret()
    return _cs.cam_vote(q_packed, rows_packed, thresholds, interpret=interpret, **kw)


@jax.jit
def binary_gemm_mxu(x_pm1: jax.Array, w_pm1: jax.Array) -> jax.Array:
    """MXU path: +-1 int8 operands on the systolic array.

    x: [..., K], w: [K, N] in {-1,+1}. Accumulates in int32 (exact for
    K < 2^31). On TPU this hits the int8 MXU at 2x bf16 throughput; the
    packed-VPU kernel wins when the workload is HBM-bandwidth-bound
    (weights 16x smaller). See DESIGN.md roofline discussion.
    """
    y = jax.lax.dot_general(
        x_pm1.astype(jnp.int8),
        w_pm1.astype(jnp.int8),
        (((x_pm1.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return y

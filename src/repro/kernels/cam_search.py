"""Pallas TPU kernel: fused multi-threshold CAM vote (Algorithm 1, fused).

The silicon executes the output layer once per HD-tolerance setting (33
analog re-tunes).  On TPU the tolerance is an integer register, so the
entire sweep fuses into ONE pass over the array: compute the Hamming
distance of every (query, class-row) pair once, then count, in-register,
how many thresholds each distance clears:

    votes[b, c] = #{ t : HD(q_b, row_c) <= T_t }

which in the noiseless limit is bit-identical to the 33-pass silicon flow
(tests/test_kernels.py asserts this against core.ensemble.votes_faithful).

The threshold vector (33 int32) is broadcast to every grid cell as a
whole-array block; HD temporaries never leave VMEM — the fusion removes
32/33 of the array reads, the TPU translation of the paper's observation
that re-tuning is the expensive step worth amortizing (Sec. V-B).

Silicon mode (DESIGN.md §8): `thr_samples` swaps the shared schedule for
a [B, C, P] float32 block of noise-sampled thresholds (from
`core/physics.SearchPhysics.sample`); randomness never enters the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.binary_gemm import _pad_axis


def _cam_vote_kernel(q_ref, rows_ref, thr_ref, out_ref, *, chunk: int,
                     noisy: bool = False):
    """votes[bq, bc] for one (query-block, class-block) grid cell.

    noisy=True: thr_ref is a [bq, bc, P] float32 block of noise-sampled
    per-(query, row, pass) thresholds (physics.SearchPhysics.sample output)
    instead of the shared [P] schedule — the HD is still computed once.
    """
    kw = q_ref.shape[-1]
    n_chunks = kw // chunk

    def body(c, acc):
        qs = q_ref[:, pl.ds(c * chunk, chunk)]
        rs = rows_ref[:, pl.ds(c * chunk, chunk)]
        xor = jax.lax.bitwise_xor(qs[:, None, :], rs[None, :, :])
        pc = jax.lax.population_count(xor).astype(jnp.int32)
        return acc + pc.sum(axis=-1)

    hd = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros(out_ref.shape, jnp.int32)
    )
    if noisy:
        thr = thr_ref[...]  # [bq, bc, P] float32 sampled thresholds
        votes = (hd[:, :, None].astype(jnp.float32) <= thr).astype(
            jnp.int32
        ).sum(-1)
    else:
        thr = thr_ref[...]  # [P] HD tolerances
        votes = (hd[:, :, None] <= thr[None, None, :]).astype(
            jnp.int32
        ).sum(-1)
    out_ref[...] = votes


@functools.partial(
    jax.jit, static_argnames=("bq", "bc", "chunk", "interpret")
)
def cam_vote(
    q_packed: jax.Array,
    rows_packed: jax.Array,
    thresholds: jax.Array,
    *,
    bq: int = 128,
    bc: int = 128,
    chunk: int = 8,
    interpret: bool = False,
    thr_samples: jax.Array | None = None,
) -> jax.Array:
    """Fused Algorithm-1 vote counts.

    q_packed    : [B, Kw] uint32 packed queries (bias searchlines included)
    rows_packed : [C, Kw] uint32 packed class rows (bias cells included)
    thresholds  : [P] HD tolerances (any order; int or calibrated float)
    thr_samples : optional [B, C, P] float32 noise-sampled thresholds
                  (physics.SearchPhysics.sample output, moveaxis'd) — the
                  silicon-noise path; replaces `thresholds` in the compare
                  while the HD-once amortization is unchanged
    returns     : [B, C] int32 votes
    """
    q, b0 = _pad_axis(q_packed, 0, bq)
    r, c0 = _pad_axis(rows_packed, 0, bc)
    q, _ = _pad_axis(q, 1, chunk)
    r, _ = _pad_axis(r, 1, chunk)
    b, kw = q.shape
    c = r.shape[0]
    if jnp.issubdtype(thresholds.dtype, jnp.floating):
        thr = thresholds.astype(jnp.float32)
    else:
        thr = thresholds.astype(jnp.int32)
    p = thr.shape[0]
    grid = (b // bq, c // bc)
    noisy = thr_samples is not None
    if noisy:
        if thr_samples.shape != (q_packed.shape[0], rows_packed.shape[0], p):
            raise ValueError(
                f"thr_samples shape {thr_samples.shape} != "
                f"[{q_packed.shape[0]}, {rows_packed.shape[0]}, {p}]"
            )
        ts, _ = _pad_axis(thr_samples.astype(jnp.float32), 0, bq)
        ts, _ = _pad_axis(ts, 1, bc)
        thr_operand = ts
        thr_spec = pl.BlockSpec((bq, bc, p), lambda i, j: (i, j, 0))
    else:
        thr_operand = thr
        thr_spec = pl.BlockSpec((p,), lambda i, j: (0,))
    out = pl.pallas_call(
        functools.partial(_cam_vote_kernel, chunk=chunk, noisy=noisy),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, kw), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, kw), lambda i, j: (j, 0)),
            thr_spec,
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.int32),
        interpret=interpret,
    )(q, r, thr_operand)
    return out[:b0, :c0]

"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def binary_gemm_hd_ref(x_packed, w_packed) -> jax.Array:
    """Pairwise Hamming distance: [M, Kw] x [N, Kw] -> [M, N] int32."""
    xor = jax.lax.bitwise_xor(x_packed[:, None, :], w_packed[None, :, :])
    return jax.lax.population_count(xor).astype(jnp.int32).sum(-1)


def cam_vote_ref(q_packed, rows_packed, thresholds) -> jax.Array:
    """Fused multi-threshold vote: [B, C] int32."""
    hd = binary_gemm_hd_ref(q_packed, rows_packed)
    return (hd[:, :, None] <= thresholds.astype(jnp.int32)).sum(-1).astype(
        jnp.int32
    )


def bitlinear_ref(x, w, n_bits: int | None = None) -> jax.Array:
    """+-1-domain binary matmul oracle: y = x @ w with x,w in {-1,+1}.

    x: [..., K] float/int +-1;  w: [K, N] +-1.  Returns float32 [..., N].
    """
    return jnp.asarray(x, jnp.float32) @ jnp.asarray(w, jnp.float32)

"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def binary_gemm_hd_ref(x_packed, w_packed) -> jax.Array:
    """Pairwise Hamming distance: [M, Kw] x [N, Kw] -> [M, N] int32."""
    xor = jax.lax.bitwise_xor(x_packed[:, None, :], w_packed[None, :, :])
    return jax.lax.population_count(xor).astype(jnp.int32).sum(-1)


def cam_vote_ref(q_packed, rows_packed, thresholds) -> jax.Array:
    """Fused multi-threshold vote: [B, C] int32."""
    hd = binary_gemm_hd_ref(q_packed, rows_packed)
    return (hd[:, :, None] <= thresholds.astype(jnp.int32)).sum(-1).astype(
        jnp.int32
    )


def bitlinear_ref(x, w, n_bits: int | None = None) -> jax.Array:
    """+-1-domain binary matmul oracle: y = x @ w with x,w in {-1,+1}.

    x: [..., K] float/int +-1;  w: [K, N] +-1.  Returns float32 [..., N].
    """
    return jnp.asarray(x, jnp.float32) @ jnp.asarray(w, jnp.float32)


def binary_conv2d_ref(x_pm1, w_pm1, stride: int = 1) -> jax.Array:
    """±1-domain VALID conv oracle: the unpacked ground truth.

    x_pm1: [B, H, W, C] ±1 activations;  w_pm1: [O, K, K, C] ±1 filters
    (CAM-row layout, `convnet.FoldedConvLayer.weights_pm1`).  Returns
    float32 [B, OH, OW, O] dot products — each output position is the
    XNOR-popcount dot of its K*K*C patch against every filter row
    (== n_bits - 2*HD in the packed domain).
    """
    x = jnp.asarray(x_pm1, jnp.float32)
    w = jnp.asarray(w_pm1, jnp.float32)
    # conv_general_dilated computes a true convolution-as-correlation
    # with HWIO kernels, so transpose the row layout [O,K,K,C]->[K,K,C,O]
    return jax.lax.conv_general_dilated(
        x, jnp.transpose(w, (1, 2, 3, 0)),
        window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv_votes_ref(folded, head, x01, encoding, side: int) -> jax.Array:
    """Unpacked end-to-end-binary CNN oracle: raw pixels -> vote counts.

    The ground truth for `kernels/fused_conv.py` and the conv pipeline:
    encode [0,1] pixels [B, side*side] through the binary input layer,
    run every FoldedConvLayer as sign(conv + C) in ±1 floats, flatten
    NHWC, run the folded FC hidden layers as sign(Wx + C), and vote the
    head with `ensemble.votes_fused`.  Bit-exactness of the packed
    fused path against this oracle is asserted in tests/test_conv.py.
    """
    from repro.core.convnet import FoldedConvLayer
    from repro.core.ensemble import votes_fused

    b = jnp.asarray(x01).shape[0]
    h = encoding.encode_pm1(
        jnp.asarray(x01).reshape(b, side, side)
    )
    flat = None
    for layer in folded[:-1]:
        if isinstance(layer, FoldedConvLayer):
            y = binary_conv2d_ref(h, layer.weights_pm1, layer.stride)
            h = jnp.where(y + jnp.asarray(layer.c, jnp.float32) >= 0,
                          1.0, -1.0)
        else:
            if flat is None:
                h, flat = h.reshape(b, -1), True
            y = h @ jnp.asarray(layer.weights_pm1.T, jnp.float32)
            h = jnp.where(y + jnp.asarray(layer.c, jnp.float32) >= 0,
                          1.0, -1.0)
    if flat is None:
        h = h.reshape(b, -1)
    return votes_fused(head, h)

"""Pallas TPU kernels for the paper's compute hot spots.

  binary_gemm — bit-packed XNOR-popcount GEMM (the CAM matchline array,
                adapted to VPU popcount over uint32 words)
  cam_search  — fused multi-threshold CAM vote (Algorithm 1 in one pass)
  fused_mlp   — the ENTIRE deployed BNN in one pass: packed matvec + bias
                + sign + in-register repack per layer, vote at the head;
                hidden activations never leave VMEM
  fused_conv  — the conv sibling: packed-domain binary convolution with
                im2col folded into the channel-packed layout (per-tap
                strided slices of the VMEM-resident feature map), then
                the fused_mlp FC/vote tail — the end-to-end-binary CNN
                workload in one pass
  ops         — jit'd public wrappers (interpret-mode on CPU)
  ref         — pure-jnp oracles used by the test suite

Kernels are validated in interpret mode on CPU (bit-exact) and target TPU
Mosaic for deployment; block shapes are chosen so every working set fits
VMEM with MXU/VPU-aligned tile dims (multiples of 8x128 for int32).
"""

from repro.kernels import ops, ref  # noqa: F401

"""Pallas TPU kernel: the ENTIRE deployed BNN in one fused packed-domain pass.

The paper's headline property is that weights AND activations never leave
the binary domain: hidden activations are regenerated inside the CAM
array, layer after layer, with no full-precision round trip (that is what
buys 560 K inf/s at 0.8 mW).  The layer-by-layer TPU translation loses
this: each `sign(Wx + C)` used to return unpacked ±1 floats to HBM, get
re-packed by a host-level `pack_bits`, and only then feed the next layer
(three HBM round trips per layer).

This kernel is the TPU translation of "activations stay in the array"
(DESIGN.md §4): ONE `pallas_call` per batch block executes

    per hidden layer:  tiled XNOR-popcount matvec over packed uint32 rows
                       -> + C_j integer bias add -> sign
                       -> in-register repack to uint32 words
    final layer:       fused 33-threshold CAM vote (cam_search semantics)

with every intermediate — Hamming distances, pre-sign integers, repacked
activation words — resident in VMEM/vector registers.  Only the packed
input batch enters and only the int32 vote counts leave.

Weights for the paper-scale models are tiny in packed form (784x128 bits
= 12.8 KiB) so every layer's rows are broadcast whole to each grid cell;
the VMEM working-set budget is derived in DESIGN.md §4.

Correctness bar (tests/test_pipeline.py): bit-exact against the
`bnn.folded_forward_exact` + `ensemble.votes_fused` digital oracle.

Silicon mode (DESIGN.md §8): the head vote optionally consumes a
precomputed [B, C, P] float32 block of noise-sampled per-pass thresholds
(`thr_samples`, produced by `core/physics.SearchPhysics.sample` outside
the kernel) — the HD-once/compare-P-times amortization is unchanged and
the kernel stays deterministic.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.binary_gemm import _pad_axis

WORD = 32


@dataclasses.dataclass(frozen=True)
class _LayerMeta:
    """Static shape info for one fused hidden layer."""

    n_bits: int  # logical input bits (the XNOR-popcount dot width)
    n_out: int  # neurons = activation bits produced
    kw: int  # padded packed words per row (chunk multiple)


def _hd_block(q, rows, chunk: int):
    """Hamming distances between all (query, row) pairs, chunked over K.

    q: [bq, kw] uint32 (VMEM value);  rows: [n, kw] uint32.
    The [bq, n, chunk] XOR temporary is bounded by the fori_loop.
    """
    n_chunks = q.shape[-1] // chunk

    def body(ci, acc):
        qs = jax.lax.dynamic_slice_in_dim(q, ci * chunk, chunk, axis=1)
        rs = jax.lax.dynamic_slice_in_dim(rows, ci * chunk, chunk, axis=1)
        xor = jax.lax.bitwise_xor(qs[:, None, :], rs[None, :, :])
        pc = jax.lax.population_count(xor).astype(jnp.int32)
        return acc + pc.sum(axis=-1)

    init = jnp.zeros((q.shape[0], rows.shape[0]), jnp.int32)
    return jax.lax.fori_loop(0, n_chunks, body, init)


def _repack(bits_u32, kw: int):
    """{0,1} uint32 bits [bq, kw*32] -> packed words [bq, kw] (in-register).

    Little-endian within each word, matching `binarize.pack_bits`.
    """
    bq = bits_u32.shape[0]
    shaped = bits_u32.reshape(bq, kw, WORD)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return (shaped << shifts).sum(axis=-1, dtype=jnp.uint32)


def _make_kernel(
    metas: Sequence[_LayerMeta],
    head_kw: int,
    bias_cells: int,
    chunk: int,
    noisy: bool = False,
):
    """Build the fused kernel body for a static layer stack.

    noisy=True swaps the shared [P] int32 threshold operand for a
    per-(query, class, pass) float32 sample block [bq, C, P] — the
    precomputed output of `physics.SearchPhysics.sample` (the kernel
    itself stays deterministic; all randomness is sampled outside).  The
    HD-once / compare-P-times amortization is unchanged.
    """

    def kernel(*refs):
        x_ref = refs[0]
        out_ref = refs[-1]
        thr_ref = refs[-2]
        head_ref = refs[-3]

        q = x_ref[...]  # [bq, kw0] packed input activations
        bq = q.shape[0]
        for i, m in enumerate(metas):
            w = refs[1 + 2 * i][...]  # [n_out, kw] packed weight rows
            c = refs[2 + 2 * i][...]  # [n_out] int32 folded BN constants
            hd = _hd_block(q, w, chunk)
            y = (m.n_bits - 2 * hd) + c[None, :]  # Eq. (3) pre-sign int
            bits = (y >= 0).astype(jnp.uint32)  # sign, 0 -> +1
            if i + 1 < len(metas):
                tail_kw, tail_bias = metas[i + 1].kw, 0
            else:
                tail_kw, tail_bias = head_kw, bias_cells
            parts = [bits]
            if tail_bias:
                # bias searchlines always driven to logic '1'
                parts.append(jnp.ones((bq, tail_bias), jnp.uint32))
            pad = tail_kw * WORD - m.n_out - tail_bias
            if pad:
                parts.append(jnp.zeros((bq, pad), jnp.uint32))
            q = _repack(
                jnp.concatenate(parts, axis=-1) if len(parts) > 1 else bits,
                tail_kw,
            )
        head = head_ref[...]  # [C, head_kw] packed class rows (bias incl.)
        hd = _hd_block(q, head, chunk)
        if noisy:
            thr = thr_ref[...]  # [bq, C, P] float32 sampled thresholds
            votes = (hd[:, :, None].astype(jnp.float32) <= thr).astype(
                jnp.int32
            )
        else:
            thr = thr_ref[...]  # [P] HD tolerances (shared by every query)
            votes = (hd[:, :, None] <= thr[None, None, :]).astype(jnp.int32)
        out_ref[...] = votes.sum(-1)

    return kernel


def _pad_words(a, chunk: int):
    """Pad packed words on the last axis to a chunk multiple (zero words)."""
    return _pad_axis(a, a.ndim - 1, chunk)[0]


@functools.partial(
    jax.jit,
    static_argnames=("layer_n_bits", "bias_cells", "bq", "chunk", "interpret"),
)
def fused_mlp_votes(
    x_packed: jax.Array,
    layer_ws: tuple[jax.Array, ...],
    layer_cs: tuple[jax.Array, ...],
    layer_n_bits: tuple[int, ...],
    head_rows: jax.Array,
    thresholds: jax.Array,
    *,
    bias_cells: int,
    bq: int = 256,
    chunk: int = 4,
    interpret: bool = False,
    thr_samples: jax.Array | None = None,
) -> jax.Array:
    """Fused end-to-end deployed-BNN vote counts.

    x_packed    : [B, Kw0] uint32 — packed ±1 input activations
    layer_ws    : per hidden layer [N_l, Kw_l] uint32 packed weight rows
    layer_cs    : per hidden layer [N_l] int32 folded BN constants
    layer_n_bits: per hidden layer logical input bit count
    head_rows   : [C, Kw_h] uint32 packed class rows (bias cells included)
    thresholds  : [P] HD tolerances (Algorithm 1 sweep; int32 for the
                  ideal sweep, float32 for calibrated knob-achieved values)
    bias_cells  : bias searchlines appended to the head query
    thr_samples : optional [B, C, P] float32 noise-sampled per-pass
                  thresholds (from `physics.SearchPhysics.sample`);
                  replaces `thresholds` in the head compare — the
                  silicon-noise fused path.  Sampling happens OUTSIDE the
                  kernel; the kernel only consumes.
    returns     : [B, C] int32 vote counts (== ensemble.votes_fused, or
                  ensemble.votes_fused_noisy when thr_samples is given)

    With no hidden layers, `x_packed` must already be the head query
    (activation bits + bias drive bits), as built by `cam.query_with_bias`.
    """
    if len(layer_ws) != len(layer_cs) or len(layer_ws) != len(layer_n_bits):
        raise ValueError("layer_ws / layer_cs / layer_n_bits length mismatch")

    x, b0 = _pad_axis(x_packed, 0, bq)
    x = _pad_words(x, chunk)
    head = _pad_words(head_rows, chunk)
    n_classes = head.shape[0]
    if jnp.issubdtype(thresholds.dtype, jnp.floating):
        thr = thresholds.astype(jnp.float32)
    else:
        thr = thresholds.astype(jnp.int32)

    metas = []
    operands = [x]
    specs = [pl.BlockSpec((bq, x.shape[1]), lambda i: (i, 0))]

    def _whole(shape):
        nd = len(shape)
        if nd == 1:
            return pl.BlockSpec(shape, lambda i: (0,))
        return pl.BlockSpec(shape, lambda i: (0, 0))

    for w, c, n_bits in zip(layer_ws, layer_cs, layer_n_bits):
        w = _pad_words(w, chunk)
        metas.append(_LayerMeta(n_bits=n_bits, n_out=w.shape[0], kw=w.shape[1]))
        operands += [w, c.astype(jnp.int32)]
        specs += [_whole(w.shape), _whole(c.shape)]
    noisy = thr_samples is not None
    if noisy:
        if thr_samples.shape[1:] != (n_classes, thr.shape[0]):
            raise ValueError(
                f"thr_samples shape {thr_samples.shape} != "
                f"[B, {n_classes}, {thr.shape[0]}]"
            )
        ts, _ = _pad_axis(thr_samples.astype(jnp.float32), 0, bq)
        p = ts.shape[-1]
        operands += [head, ts]
        specs += [
            _whole(head.shape),
            pl.BlockSpec((bq, n_classes, p), lambda i: (i, 0, 0)),
        ]
    else:
        operands += [head, thr]
        specs += [_whole(head.shape), _whole(thr.shape)]

    # shape discipline: the input must line up with its first operand —
    # a mismatch (e.g. a head-only query packed WITHOUT the bias drive
    # bits) would otherwise silently truncate the HD loop and return
    # wrong votes
    first_kw = (layer_ws[0] if metas else head_rows).shape[1]
    if x_packed.shape[1] != first_kw:
        raise ValueError(
            f"x_packed width {x_packed.shape[1]} does not match the first "
            f"operand's packed width {first_kw}; for a head-only net the "
            "query must include the bias drive bits (cam.query_with_bias)"
        )
    # ... and each repack target must hold the produced bits
    if metas:
        for prev, nxt in zip(metas[:-1], metas[1:]):
            assert prev.n_out <= nxt.kw * WORD, (prev, nxt)
        assert metas[-1].n_out + bias_cells <= head.shape[1] * WORD
    kernel = _make_kernel(metas, head.shape[1], bias_cells, chunk, noisy)

    out = pl.pallas_call(
        kernel,
        grid=(x.shape[0] // bq,),
        in_specs=specs,
        out_specs=pl.BlockSpec((bq, n_classes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], n_classes), jnp.int32),
        interpret=interpret,
    )(*operands)
    return out[:b0]

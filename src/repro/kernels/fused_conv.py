"""Pallas kernel: the ENTIRE deployed binary CNN in one fused packed pass.

The conv sibling of `kernels/fused_mlp.py`, extending the paper's
"activations never leave the binary domain" property to convolutional
workloads (the dominant related-work axis — XNORBIN, ChewBaccaNN).  ONE
`pallas_call` per batch block executes

    per conv layer:   im2col folded into the packed layout — each of the
                      k*k taps is a strided slice of the VMEM-resident
                      channel-packed feature map, XNOR-popcount
                      accumulated against the filter rows' tap words
                      (no [B*OH*OW, k*k*C] patch matrix ever exists)
                      -> + C_o integer bias add -> sign
                      -> in-register channel repack to uint32 words
    flatten:          NHWC word concatenation (per-position alignment,
                      DESIGN.md §10) + bias drive words when the head
                      is direct
    per FC layer:     the fused_mlp hidden-layer step (packed matvec +
                      C + sign + repack)
    head:             fused multi-threshold CAM vote (33 compares
                      against one Hamming distance)

Only the channel-packed input feature map enters and only the int32
vote counts leave; every intermediate — per-tap XOR temporaries,
pre-sign integers, repacked feature maps — is VMEM/register resident.

Layout conventions (DESIGN.md §10):
  * feature maps are channel-packed NHWC: [B, H, W, Cw] uint32, channel
    bits little-endian within each pixel's words, zero-padded to the
    word boundary per pixel;
  * filter rows are tap-major: [c_out, k*k*Cw] with word
    (dy*k + dx)*Cw + w holding tap (dy, dx)'s channel word w — exactly
    the order the strided-slice patch gather produces;
  * the flatten keeps the per-position word padding, so the first FC
    layer's rows must be packed with `pack_fc_rows_positionwise`
    (a plain `pack_bits` when c_out % 32 == 0 — the configs' choice).
  Pad bits are zero on BOTH operands of every Hamming distance, so they
  never contribute; logical dot widths stay k*k*c_in.

Correctness bar (tests/test_conv.py): bit-exact against the unpacked
±1 oracle `kernels.ref.conv_votes_ref` on multiple input sizes.

Silicon mode: identical contract to fused_mlp — an optional [B, C, P]
float32 `thr_samples` operand (from `physics.SearchPhysics.sample`)
replaces the shared thresholds in the head compare; the kernel itself
stays deterministic.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import binarize
from repro.kernels.binary_gemm import _pad_axis
from repro.kernels.fused_mlp import (
    _LayerMeta,
    _hd_block,
    _pad_words,
    _repack,
)

WORD = 32


@dataclasses.dataclass(frozen=True)
class ConvMeta:
    """Static shape info for one fused conv layer (square feature maps)."""

    side: int  # input feature-map side
    cw_in: int  # packed channel words per input pixel
    k: int  # kernel side
    stride: int
    out_side: int  # VALID output side
    c_out: int  # output channels = bits produced per position
    cw_out: int  # packed channel words per output pixel
    n_bits: int  # logical dot width: k * k * c_in


def conv_metas_for(conv_layers: Sequence, side: int) -> tuple[ConvMeta, ...]:
    """Static ConvMeta chain for a conv stack on `side` x `side` input."""
    metas = []
    s = side
    for layer in conv_layers:
        if s < layer.k:
            raise ValueError(
                f"feature side {s} < kernel {layer.k} (layer {len(metas)})"
            )
        out = (s - layer.k) // layer.stride + 1
        metas.append(ConvMeta(
            side=s,
            cw_in=binarize.packed_width(layer.c_in),
            k=layer.k,
            stride=layer.stride,
            out_side=out,
            c_out=layer.c_out,
            cw_out=binarize.packed_width(layer.c_out),
            n_bits=layer.n_bits,
        ))
        s = out
    return tuple(metas)


def pack_conv_rows(layer) -> jax.Array:
    """FoldedConvLayer filters -> tap-major packed rows [c_out, k*k*Cw].

    Each filter's bits are packed per tap along the channel axis (same
    per-pixel word padding as the feature map), then taps concatenate
    in (dy, dx) scan order — the order `_conv_layer_packed`'s strided
    slices visit them.
    """
    bits = (np.asarray(layer.weights_pm1) > 0).astype(np.uint8)
    c_out, k = layer.c_out, layer.k
    words = binarize.np_pack_bits(bits.reshape(c_out * k * k, layer.c_in))
    return jnp.asarray(words.reshape(c_out, k * k * words.shape[-1]))


def pack_fc_rows_positionwise(w_bits: np.ndarray, n_pos: int,
                              c: int) -> jax.Array:
    """FC rows [n_out, n_pos*c] -> packed words matching the flatten.

    The conv flatten keeps each position's channel words padded to the
    word boundary, so the FIRST FC layer after the flatten must pack
    its weight rows with the same per-position alignment: bit (p, j)
    lands in word p*Cw + j//32.  Degenerates to a plain `pack_bits`
    when c % 32 == 0.  Pad bits are zero on both operands, so logical
    dot widths are unchanged.
    """
    n_out = w_bits.shape[0]
    if w_bits.shape[1] != n_pos * c:
        raise ValueError(
            f"rows have {w_bits.shape[1]} bits, expected {n_pos}*{c}"
        )
    words = binarize.np_pack_bits(
        np.asarray(w_bits, np.uint8).reshape(n_out * n_pos, c)
    )
    return jnp.asarray(words.reshape(n_out, n_pos * words.shape[-1]))


def bias_drive_words(bias_cells: int) -> np.ndarray:
    """Packed all-ones bias searchline words (logic '1' drive bits)."""
    return binarize.np_pack_bits(
        np.ones((1, bias_cells), np.uint8)
    )[0]


def conv_hd_packed(x, w, m: ConvMeta):
    """Per-position Hamming distances of one packed conv layer.

    x: [B, S, S, Cw] uint32; w: [c_out, k*k*Cw] tap-major rows.
    Returns [B, O, O, c_out] int32.  The im2col never materializes: tap
    (dy, dx) is a strided slice of the feature map, XNOR-popcount-
    accumulated against the filters' tap words.  Pure jnp on values —
    shared by the Pallas kernel body, the XLA twin, and the unpacked
    layer-by-layer benchmark baseline, so the tap geometry cannot
    drift between them.
    """
    b = x.shape[0]
    hd = jnp.zeros((b, m.out_side, m.out_side, m.c_out), jnp.int32)
    span = (m.out_side - 1) * m.stride + 1
    for dy in range(m.k):
        for dx in range(m.k):
            xs = jax.lax.slice(
                x, (0, dy, dx, 0),
                (b, dy + span, dx + span, m.cw_in),
                (1, m.stride, m.stride, 1),
            )  # [B, O, O, Cw]
            tap = jax.lax.slice_in_dim(
                w, (dy * m.k + dx) * m.cw_in, (dy * m.k + dx + 1) * m.cw_in,
                axis=1,
            )  # [c_out, Cw]
            xor = jax.lax.bitwise_xor(
                xs[:, :, :, None, :], tap[None, None, None, :, :]
            )  # [B, O, O, c_out, Cw] — the bounded per-tap temporary
            hd = hd + jax.lax.population_count(xor).astype(jnp.int32).sum(-1)
    return hd


def _conv_layer_packed(x, w, c, m: ConvMeta):
    """One packed-domain conv layer: [B, S, S, Cw] -> [B, O, O, Cw_out].

    Pure jnp on values — the SAME function is the Pallas kernel body's
    layer step (on VMEM-loaded blocks) and the XLA twin's (on arrays);
    the two implementations cannot drift apart.
    """
    b = x.shape[0]
    hd = conv_hd_packed(x, w, m)
    y = (m.n_bits - 2 * hd) + c[None, None, None, :]  # Eq. (3) pre-sign
    bits = (y >= 0).astype(jnp.uint32)  # sign, 0 -> +1
    pad = m.cw_out * WORD - m.c_out
    if pad:
        bits = jnp.concatenate(
            [bits,
             jnp.zeros((b, m.out_side, m.out_side, pad), jnp.uint32)],
            axis=-1,
        )
    shaped = bits.reshape(b, m.out_side, m.out_side, m.cw_out, WORD)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return (shaped << shifts).sum(axis=-1, dtype=jnp.uint32)


def conv_stage_packed(x, conv_ws, conv_cs, metas, bias_words=None):
    """Run the conv stack + flatten in the packed domain (shared math).

    x: [B, S, S, Cw0] uint32.  Returns the flattened packed query
    [B, n_pos * Cw_f (+ bias words)] feeding the FC stage; appends the
    all-ones bias drive words when `bias_words` is given (conv -> head
    direct, word-aligned flatten required).
    """
    for w, c, m in zip(conv_ws, conv_cs, metas):
        x = _conv_layer_packed(x, w, c, m)
    q = x.reshape(x.shape[0], -1)
    if bias_words is not None:
        bw = jnp.asarray(bias_words, jnp.uint32)
        q = jnp.concatenate(
            [q, jnp.broadcast_to(bw, (q.shape[0], bw.shape[0]))], axis=-1
        )
    return q


def _make_kernel(conv_metas, mlp_metas, head_kw: int, bias_cells: int,
                 chunk: int, noisy: bool, has_bias_ref: bool):
    """Fused conv+MLP+vote kernel body for a static layer stack.

    Ref order: x, (conv_w, conv_c)*, (fc_w, fc_c)*, [bias_words,]
    head, thr, out — the bias-drive words operand is present only on
    the head-direct path.  The FC/head tail is the fused_mlp step (same
    helpers); `noisy` swaps the shared [P] thresholds for a [bq, C, P]
    sample block.
    """

    def kernel(*refs):
        x_ref = refs[0]
        out_ref = refs[-1]
        thr_ref = refs[-2]
        head_ref = refs[-3]

        x = x_ref[...]  # [bq, S, S, Cw0] channel-packed input
        bq = x.shape[0]
        conv_w = [refs[1 + 2 * i][...] for i in range(len(conv_metas))]
        conv_c = [refs[2 + 2 * i][...] for i in range(len(conv_metas))]
        idx = 1 + 2 * len(conv_metas)
        # conv stack + flatten (+ bias drive words on the head-direct
        # path): the SAME shared lowering the XLA twin executes
        q = conv_stage_packed(
            x, conv_w, conv_c, conv_metas,
            refs[-4][...] if has_bias_ref else None,
        )
        target_kw = mlp_metas[0].kw if mlp_metas else head_kw
        if q.shape[1] < target_kw:
            q = jnp.concatenate(
                [q, jnp.zeros((bq, target_kw - q.shape[1]), jnp.uint32)],
                axis=-1,
            )
        for i, m in enumerate(mlp_metas):
            w = refs[idx][...]
            c = refs[idx + 1][...]
            idx += 2
            hd = _hd_block(q, w, chunk)
            y = (m.n_bits - 2 * hd) + c[None, :]
            bits = (y >= 0).astype(jnp.uint32)
            if i + 1 < len(mlp_metas):
                tail_kw, tail_bias = mlp_metas[i + 1].kw, 0
            else:
                tail_kw, tail_bias = head_kw, bias_cells
            parts = [bits]
            if tail_bias:
                parts.append(jnp.ones((bq, tail_bias), jnp.uint32))
            pad = tail_kw * WORD - m.n_out - tail_bias
            if pad:
                parts.append(jnp.zeros((bq, pad), jnp.uint32))
            q = _repack(
                jnp.concatenate(parts, axis=-1) if len(parts) > 1 else bits,
                tail_kw,
            )
        head = head_ref[...]
        hd = _hd_block(q, head, chunk)
        if noisy:
            thr = thr_ref[...]  # [bq, C, P] sampled thresholds
            votes = (hd[:, :, None].astype(jnp.float32) <= thr).astype(
                jnp.int32
            )
        else:
            thr = thr_ref[...]  # [P] shared tolerances
            votes = (hd[:, :, None] <= thr[None, None, :]).astype(jnp.int32)
        out_ref[...] = votes.sum(-1)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("conv_metas", "layer_n_bits", "bias_cells", "bq",
                     "chunk", "interpret", "head_direct"),
)
def fused_conv_votes(
    x_packed: jax.Array,
    conv_ws: tuple[jax.Array, ...],
    conv_cs: tuple[jax.Array, ...],
    conv_metas: tuple[ConvMeta, ...],
    layer_ws: tuple[jax.Array, ...],
    layer_cs: tuple[jax.Array, ...],
    layer_n_bits: tuple[int, ...],
    head_rows: jax.Array,
    thresholds: jax.Array,
    *,
    bias_cells: int,
    bq: int = 64,
    chunk: int = 4,
    interpret: bool = False,
    head_direct: bool = False,
    thr_samples: jax.Array | None = None,
) -> jax.Array:
    """Fused end-to-end binary-CNN vote counts (one kernel per block).

    x_packed    : [B, S, S, Cw0] uint32 — channel-packed encoded input
                  (`binarize.pack_bits` of the InputEncoding bits)
    conv_ws     : per conv layer [c_out, k*k*Cw] tap-major packed rows
                  (`pack_conv_rows`)
    conv_cs     : per conv layer [c_out] int32 folded BN constants
    conv_metas  : static `conv_metas_for` chain (shapes/strides)
    layer_ws    : FC-stage packed rows; the FIRST must be
                  `pack_fc_rows_positionwise` (flatten alignment)
    layer_cs / layer_n_bits / head_rows / thresholds / bias_cells /
    thr_samples : exactly as in `fused_mlp.fused_mlp_votes`
    head_direct : True when there are no FC hidden layers — the flatten
                  (word-aligned: last conv c_out % 32 == 0) feeds the
                  head straight, with bias drive words appended in the
                  packed domain
    returns     : [B, C] int32 vote counts (== ref.conv_votes_ref)

    bq defaults lower than fused_mlp's (64 vs 256): the per-tap XOR
    temporary is [bq, O, O, c_out, Cw] — the VMEM budget is derived in
    DESIGN.md §10.
    """
    if len(conv_ws) != len(conv_cs) or len(conv_ws) != len(conv_metas):
        raise ValueError("conv operand/meta length mismatch")
    if len(layer_ws) != len(layer_cs) or len(layer_ws) != len(layer_n_bits):
        raise ValueError("fc operand length mismatch")
    if not conv_metas:
        raise ValueError("no conv layers — use fused_mlp.fused_mlp_votes")
    m0 = conv_metas[0]
    if x_packed.shape[1:] != (m0.side, m0.side, m0.cw_in):
        raise ValueError(
            f"x_packed shape {x_packed.shape} does not match the first "
            f"conv layer's [B, {m0.side}, {m0.side}, {m0.cw_in}]"
        )
    bias_words = None
    if head_direct:
        if layer_ws:
            raise ValueError("head_direct=True with FC hidden layers")
        if conv_metas[-1].c_out % WORD:
            raise ValueError(
                "conv -> head-direct needs a word-aligned flatten: last "
                f"conv c_out {conv_metas[-1].c_out} % 32 != 0"
            )
        bias_words = bias_drive_words(bias_cells)
    elif not layer_ws:
        raise ValueError("no FC layers and head_direct=False")

    x, b0 = _pad_axis(x_packed, 0, bq)
    head = _pad_words(head_rows, chunk)
    n_classes = head.shape[0]
    if jnp.issubdtype(thresholds.dtype, jnp.floating):
        thr = thresholds.astype(jnp.float32)
    else:
        thr = thresholds.astype(jnp.int32)

    operands = [x]
    specs = [pl.BlockSpec((bq,) + x.shape[1:],
                          lambda i: (i, 0, 0, 0))]

    def _whole(shape):
        zeros = (0,) * len(shape)
        return pl.BlockSpec(shape, lambda i, z=zeros: z)

    for w, c in zip(conv_ws, conv_cs):
        operands += [w, c.astype(jnp.int32)]
        specs += [_whole(w.shape), _whole(c.shape)]
    mlp_metas = []
    for w, c, n_bits in zip(layer_ws, layer_cs, layer_n_bits):
        w = _pad_words(w, chunk)
        mlp_metas.append(
            _LayerMeta(n_bits=n_bits, n_out=w.shape[0], kw=w.shape[1])
        )
        operands += [w, c.astype(jnp.int32)]
        specs += [_whole(w.shape), _whole(c.shape)]
    if bias_words is not None:
        bw = jnp.asarray(bias_words, jnp.uint32)
        operands.append(bw)
        specs.append(_whole(bw.shape))
    noisy = thr_samples is not None
    if noisy:
        if thr_samples.shape[1:] != (n_classes, thr.shape[0]):
            raise ValueError(
                f"thr_samples shape {thr_samples.shape} != "
                f"[B, {n_classes}, {thr.shape[0]}]"
            )
        ts, _ = _pad_axis(thr_samples.astype(jnp.float32), 0, bq)
        operands += [head, ts]
        specs += [
            _whole(head.shape),
            pl.BlockSpec((bq, n_classes, ts.shape[-1]),
                         lambda i: (i, 0, 0)),
        ]
    else:
        operands += [head, thr]
        specs += [_whole(head.shape), _whole(thr.shape)]

    kernel = _make_kernel(
        tuple(conv_metas), tuple(mlp_metas), head.shape[1], bias_cells,
        chunk, noisy, bias_words is not None,
    )
    out = pl.pallas_call(
        kernel,
        grid=(x.shape[0] // bq,),
        in_specs=specs,
        out_specs=pl.BlockSpec((bq, n_classes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], n_classes), jnp.int32),
        interpret=interpret,
    )(*operands)
    return out[:b0]

"""Pallas TPU kernel: bit-packed XNOR-popcount GEMM.

This is the TPU-native adaptation of the CAM matchline array (DESIGN.md §2):
the massively parallel per-row XNOR/popcount of the silicon becomes a
VPU-resident popcount GEMM over uint32-packed operands.  Keeping operands
bit-packed in HBM gives a 16x bandwidth advantage over bf16 and 32x over
fp32 — the memory-roofline translation of the paper's "weights never leave
the array" property.

    out[m, n] = sum_k popcount(x[m, k] XOR w[n, k])        (Hamming distance)
    dot_pm1   = n_bits - 2 * out                           (XNOR-popcount dot)

Tiling: grid over (M/bm, N/bn); the packed K dimension stays whole per
block (Kw words = n_bits/32; even d_model = 16 384 packs to 512 words = 2 KiB
per row, so a (bm + bn) * Kw * 4 B working set fits VMEM for bm = bn = 256
at < 1 MiB).  The [bm, bn, chunk] XOR temp is bounded by an inner
fori_loop over K chunks.

VMEM working set per grid cell (defaults bm=bn=256, chunk=8, Kw=512):
    X block   256*512*4   = 512 KiB
    W block   256*512*4   = 512 KiB
    XOR temp  256*256*8*4 =   2 MiB
    acc       256*256*4   = 256 KiB      -> ~3.3 MiB << 16 MiB VMEM

The MXU alternative (unpack to +-1 int8, systolic matmul) is provided in
ops.binary_gemm_mxu; the roofline crossover is discussed in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _binary_gemm_kernel(x_ref, w_ref, out_ref, *, chunk: int):
    """One (bm, bn) output tile: HD between all (x row, w row) pairs.

    x_ref: [bm, Kw] uint32 (VMEM)   w_ref: [bn, Kw] uint32 (VMEM)
    out_ref: [bm, bn] int32 — Hamming distance over the full K range.
    """
    kw = x_ref.shape[-1]
    n_chunks = kw // chunk  # Kw is padded to a chunk multiple by the wrapper

    def body(c, acc):
        xs = x_ref[:, pl.ds(c * chunk, chunk)]  # [bm, chunk]
        ws = w_ref[:, pl.ds(c * chunk, chunk)]  # [bn, chunk]
        xor = jax.lax.bitwise_xor(xs[:, None, :], ws[None, :, :])
        pc = jax.lax.population_count(xor).astype(jnp.int32)
        return acc + pc.sum(axis=-1)

    acc = jnp.zeros(out_ref.shape, jnp.int32)
    acc = jax.lax.fori_loop(0, n_chunks, body, acc)
    out_ref[...] = acc


def _pad_axis(a, axis: int, mult: int):
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a, size
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths), size


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "chunk", "interpret")
)
def binary_gemm_hd(
    x_packed: jax.Array,
    w_packed: jax.Array,
    *,
    bm: int = 256,
    bn: int = 256,
    chunk: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Pairwise Hamming distances between packed rows.

    x_packed: [M, Kw] uint32;  w_packed: [N, Kw] uint32  ->  [M, N] int32.
    Zero-padding K is sound: pad words are 0 in both operands (XOR = 0).
    """
    x, m0 = _pad_axis(x_packed, 0, bm)
    w, n0 = _pad_axis(w_packed, 0, bn)
    x, _ = _pad_axis(x, 1, chunk)
    w, _ = _pad_axis(w, 1, chunk)
    m, kw = x.shape
    n = w.shape[0]
    grid = (m // bm, n // bn)
    out = pl.pallas_call(
        functools.partial(_binary_gemm_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kw), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, kw), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, w)
    return out[:m0, :n0]

"""PiC-BNN classification serving engine: async micro-batching over the
compiled fused pipeline.

The paper's headline is a *serving* number — 560 K inf/s at 703 M
inf/s/W — and `pipeline.CompiledPipeline` is a bare batch function.
This module is the subsystem between the two: it accepts ragged streams
of single-image requests and turns them into efficiently-bucketed fused
dispatches.

    server = PicBnnServer(BatchingPolicy(max_batch=256, max_wait_us=500))
    server.register("mnist", deployment)    # or a CompiledPipeline, or a
    server.register("hg", "ckpts/hg")       # saved Deployment directory
    server.start()                       # or: with PicBnnServer(...) as s:
    h = server.submit("mnist", image)    # image: [n_in] in the ±1 domain
    res = h.result()                     # .pred, .votes, .latency_ms, ...
    server.close()
    print(server.stats().summary())

Each registered model dispatches through ONE declarative request spec
(`repro.spec.InferenceSpec`), fixed at registration: noiseless models
run `InferenceSpec()`, silicon models the per-request-key spec, MC
models the per-request MC spec with the sum reduction fused in.  The
dispatch hot path is a single `pipe.run(x, spec, keys=...)` — adding a
serving mode is a new spec value, not a new pipeline method.

Architecture (DESIGN.md §9):

  submit()/submit_many() --> MicroBatcher (serve/scheduler.py): requests
      are enqueued as contiguous LOTS (a burst is one lot; a single
      request is a lot of 1), per-model lanes, dispatch on full
      `max_batch` or the `max_wait_us` deadline, bounded admission
      (`max_queue` -> QueueFullError).  The hot path allocates one slab
      per *burst*, never per request — per-request Python cost is what
      caps a GIL-bound serving loop.
  dispatch thread: drains one lane batch (a list of lot spans),
      assembles it into a bucket-sized staging buffer with vectorized
      copies, stages to the next device round-robin (`jax.device_put`)
      and issues the jitted pipeline call.  jax dispatch is async, so
      while the device crunches batch N the dispatch thread is already
      assembling and staging batch N+1 (depth bounded by `max_inflight`).
  completion thread: blocks on device->host readback in dispatch order,
      publishes per-batch results, records metrics.

Batches dispatch into the pipeline's power-of-two bucket grid at exactly
bucket-shaped operands, so a server warms O(log max_batch) program
variants per model per device (`CompiledPipeline.warmup`) and never
compiles — not even an eager op — mid-traffic.

Determinism contract: noiseless served predictions are bit-exact equal
to a direct pipeline call on the same images (bucketing is padding-
invariant); silicon-mode requests carry a per-request PRNG key and are
served through the `noise="per_request"` specs (per-request
`batch_shape=()` draws), so results are bit-exact reproducible no matter
how the batcher happens to coalesce the stream — tested on all three
bank configurations in tests/test_serve_picbnn.py.

Device fan-out: round-robin by default — each micro-batch runs whole on
one local device, devices serve independent batches (and different
models) concurrently; the folded weights are jit-closure constants, so
XLA replicates them onto every device that executes the program.  The
explicit-mesh/GSPMD variant (`fanout="spmd"`) shards each batch over a
1-axis local mesh with the batch axis from
`sharding.rules.PICBNN_SERVE_RULES` and weights replicated — better for
latency of big single batches, worse for micro-batch throughput.  A
single-device host is simply the degenerate ring.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from pathlib import Path
from typing import Optional, Sequence

import jax
import numpy as np

from repro.core import mapping
from repro.deploy import Deployment
from repro.pipeline import CompiledPipeline, next_bucket
from repro.spec import InferenceSpec
from repro.serve.scheduler import (
    BatchingPolicy,
    LatencySummary,
    MicroBatcher,
    QueueFullError,
    latency_summary,
)
from repro.sharding import rules as shrules

__all__ = [
    "BatchingPolicy",
    "ClassifyResult",
    "GroupHandle",
    "ModelStats",
    "PicBnnServer",
    "QueueFullError",
    "ServerStats",
]


@dataclasses.dataclass(frozen=True)
class ClassifyResult:
    """One served classification + its per-request timing."""

    uid: int
    model_id: str
    pred: int
    votes: np.ndarray  # [C] int32 (MC models: summed over samples)
    queue_ms: float  # submit -> batch dispatch (coalescing + queueing)
    service_ms: float  # dispatch -> readback complete (staging + compute)
    latency_ms: float  # submit -> readback complete
    batch_size: int  # logical requests in the micro-batch served with
    bucket: int  # padded bucket the batch dispatched into
    device: int  # ring index of the device that served it (-1: spmd)


class _Slab:
    """One admitted burst: contiguous request arrays + placement map.

    `spans` is appended by the dispatch thread as the batcher carves the
    slab into micro-batches: (batch, slab_lo, batch_lo, k) means slab
    rows [slab_lo, slab_lo+k) became rows [batch_lo, batch_lo+k) of
    `batch`.  `placed` counts mapped requests; clients wait on the
    server's dispatch condition until their rows are placed.
    """

    __slots__ = ("uid0", "model_id", "x", "keys", "t_submit", "n",
                 "placed", "spans")

    def __init__(self, uid0: int, model_id: str, x: np.ndarray, keys,
                 t_submit: float):
        self.uid0 = uid0
        self.model_id = model_id
        self.x = x
        self.keys = keys
        self.t_submit = t_submit
        self.n = len(x)
        self.placed = 0
        self.spans: list = []


class _Batch:
    __slots__ = ("model_id", "n", "bucket", "device", "t_dispatch", "t_done",
                 "t_submits", "votes", "preds", "error", "event")

    def __init__(self, model_id: str, n: int, bucket: int, device: int,
                 t_dispatch: float, t_submits: np.ndarray):
        self.model_id = model_id
        self.n = n
        self.bucket = bucket
        self.device = device
        self.t_dispatch = t_dispatch
        self.t_done = 0.0
        self.t_submits = t_submits
        self.votes: Optional[np.ndarray] = None
        self.preds: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None
        self.event = threading.Event()


class GroupHandle:
    """Result handle for one submitted burst (and, via `_Handle`, for
    single requests — a burst of 1).

    Per-request Python cost is the serving throughput ceiling on a
    GIL-bound host, so the group APIs are vectorized: `wait_all` returns
    the [n] prediction array with one event-wait per underlying
    micro-batch; `results` builds the per-request ClassifyResult list
    only when asked.
    """

    __slots__ = ("_slab", "_srv")

    def __init__(self, slab: _Slab, srv: "PicBnnServer"):
        self._slab = slab
        self._srv = srv

    def __len__(self) -> int:
        return self._slab.n

    def done(self) -> bool:
        """True once every request in the burst has a published result."""
        slab = self._slab
        return slab.placed >= slab.n and all(
            b.event.is_set() for (b, _lo, _bp, _k) in slab.spans
        )

    def _wait_placed(self, deadline: Optional[float]) -> None:
        slab = self._slab
        if slab.placed >= slab.n:
            return
        cv = self._srv._dispatch_cv
        with cv:
            while slab.placed < slab.n:
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError("request(s) not dispatched in time")
                cv.wait(remaining)

    def _wait_batches(self, timeout: Optional[float]) -> list:
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        self._wait_placed(deadline)
        spans = self._slab.spans
        for batch, _lo, _bp, _k in spans:
            if not batch.event.is_set() and not batch.event.wait(
                None if deadline is None
                else max(deadline - time.perf_counter(), 0.0)
            ):
                raise TimeoutError("batch not completed in time")
            if batch.error is not None:
                raise batch.error
        return spans

    def wait_all(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until every request is served; return preds [n] int."""
        spans = self._wait_batches(timeout)
        slab = self._slab
        if len(spans) == 1 and spans[0][3] == slab.n:
            b, _lo, bp, k = spans[0]
            return b.preds[bp:bp + k]
        preds = np.empty(slab.n, np.int64)
        for batch, lo, bp, k in spans:
            preds[lo:lo + k] = batch.preds[bp:bp + k]
        return preds

    def votes_all(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until served; return vote counts [n, C] int32."""
        spans = self._wait_batches(timeout)
        slab = self._slab
        out = None
        for batch, lo, bp, k in spans:
            if out is None:
                out = np.empty((slab.n, batch.votes.shape[1]),
                               batch.votes.dtype)
            out[lo:lo + k] = batch.votes[bp:bp + k]
        return out

    def _result_at(self, i: int) -> ClassifyResult:
        slab = self._slab
        for batch, lo, bp, k in slab.spans:
            if lo <= i < lo + k:
                j = bp + (i - lo)
                return ClassifyResult(
                    uid=slab.uid0 + i,
                    model_id=batch.model_id,
                    pred=int(batch.preds[j]),
                    votes=batch.votes[j],
                    queue_ms=(batch.t_dispatch - slab.t_submit) * 1e3,
                    service_ms=(batch.t_done - batch.t_dispatch) * 1e3,
                    latency_ms=(batch.t_done - slab.t_submit) * 1e3,
                    batch_size=batch.n,
                    bucket=batch.bucket,
                    device=batch.device,
                )
        raise IndexError(i)  # unreachable after _wait_batches

    def results(self, timeout: Optional[float] = None) -> list:
        """Block until served; return per-request ClassifyResults."""
        self._wait_batches(timeout)
        return [self._result_at(i) for i in range(self._slab.n)]


class _Handle(GroupHandle):
    """Single-request handle (a burst of exactly one)."""

    __slots__ = ()

    def wait(self, timeout: Optional[float] = None) -> int:
        """Block until served; return just the predicted class (the
        no-allocation fast path — result() builds a full dataclass)."""
        return int(self.wait_all(timeout)[0])

    def result(self, timeout: Optional[float] = None) -> ClassifyResult:
        self._wait_batches(timeout)
        return self._result_at(0)


@dataclasses.dataclass
class _Model:
    """Registry entry: compiled pipeline + serving/meta attributes."""

    model_id: str
    pipe: CompiledPipeline
    silicon: bool  # requests must carry a per-request PRNG key
    spec: InferenceSpec  # the ONE spec every dispatch for this model runs
    #   (mc_samples lives inside the spec — no duplicate state)
    silicon_cost: Optional[mapping.InferenceCost]  # Table-II equivalent


@dataclasses.dataclass(frozen=True)
class ModelStats:
    model_id: str
    n_requests: int
    n_batches: int
    mean_batch: float
    mean_occupancy: float  # logical batch / padded bucket (1 = no waste)
    inf_per_s: float  # over this model's active window
    latency: LatencySummary
    queue: LatencySummary
    service: LatencySummary
    silicon_inf_per_s: Optional[float]  # mapping.model_inference_cost
    vs_silicon: Optional[float]  # achieved / silicon-equivalent


@dataclasses.dataclass(frozen=True)
class ServerStats:
    """Aggregate serving report (see summary())."""

    n_requests: int
    n_batches: int
    wall_s: float  # first dispatch -> last completion
    inf_per_s: float
    mean_batch: float
    mean_occupancy: float
    queue_high_water: int
    latency: LatencySummary
    queue: LatencySummary
    service: LatencySummary
    per_model: dict[str, ModelStats]

    def summary(self) -> str:
        """Human-readable multi-line serving report."""
        lines = [
            f"served {self.n_requests} requests in {self.n_batches} "
            f"batches over {self.wall_s:.3f}s -> {self.inf_per_s:,.0f} "
            f"inf/s (mean batch {self.mean_batch:.1f}, occupancy "
            f"{self.mean_occupancy:.2f}, queue high-water "
            f"{self.queue_high_water})",
            f"  latency  {self.latency}",
            f"  queue    {self.queue}",
            f"  service  {self.service}",
        ]
        for ms in self.per_model.values():
            line = (f"  [{ms.model_id}] {ms.n_requests} reqs @ "
                    f"{ms.inf_per_s:,.0f} inf/s, p99 "
                    f"{ms.latency.p99_ms:.3f} ms")
            if ms.silicon_inf_per_s:
                line += (f" — silicon-equivalent {ms.silicon_inf_per_s:,.0f}"
                         f" inf/s (x{ms.vs_silicon:.3f} of Table II)")
            lines.append(line)
        return "\n".join(lines)


class PicBnnServer:
    """Async micro-batching classification server over compiled pipelines.

    Thread model: N client threads call submit()/submit_many(); one
    dispatch thread coalesces + stages + issues jitted calls; one
    completion thread blocks on readbacks and publishes results.
    `close()` drains everything already admitted, then joins both
    threads.
    """

    def __init__(self, policy: BatchingPolicy = BatchingPolicy(), *,
                 devices: Optional[Sequence] = None,
                 fanout: str = "round_robin",
                 stats_window: int = 4096):
        if fanout not in ("round_robin", "spmd"):
            raise ValueError(f"unknown fanout {fanout!r}")
        self.policy = policy
        self.stats_window = stats_window
        self.devices = list(devices) if devices else jax.local_devices()
        self.fanout = fanout
        self._mesh = None
        self._batch_sharding = None
        if fanout == "spmd":
            self._mesh = shrules.serve_mesh(self.devices)
            self._batch_sharding = shrules.batch_sharding(self._mesh)
        self._models: dict[str, _Model] = {}
        self._batcher = MicroBatcher(policy)
        self._inflight: list = []
        self._inflight_cond = threading.Condition()
        # percentile metrics come from a BOUNDED window of recent batch
        # records (each retains its votes array — unbounded retention
        # would leak MB/s at sustained load); counts and the throughput
        # window are tracked as running totals so they stay lifetime-
        # accurate however small the window is
        self._records: "collections.deque[_Batch]" = collections.deque(
            maxlen=stats_window
        )
        self._totals: dict[str, list] = {}  # model -> [n, batches, t0, t1]
        self._records_lock = threading.Lock()
        self._dispatch_cv = threading.Condition()
        self._uid = 0
        self._uid_lock = threading.Lock()
        self._next_dev = 0
        self._started = False
        self._closed = False
        self._dispatch_t: Optional[threading.Thread] = None
        self._complete_t: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    # registry
    # ------------------------------------------------------------------
    def register(self, model_id: str, model, *,
                 layer_sizes: Optional[Sequence[int]] = None,
                 silicon_cost: Optional[mapping.InferenceCost] = None,
                 mc_samples: int = 0, warmup: bool = False) -> None:
        """Add a model to the registry.

        model : a `CompiledPipeline`, a `deploy.Deployment` (compiled
            lazily), or a str/Path to a SAVED deployment directory
            (`Deployment.save` output — servers register models straight
            from disk).  MLP deployments take ±1 activation requests of
            width `pipe.n_in`, conv deployments raw [0,1] pixel requests
            of width image_side**2; the serving layer only sees [n_in]
            request rows either way.

        layer_sizes : optional (n_in, ..., n_classes) of a deployed MLP
            — enables the Table-II silicon-equivalent throughput in
            stats() via `mapping.model_inference_cost`.  Derived
            automatically from a pure-MLP Deployment.
        silicon_cost: alternative to layer_sizes for non-MLP graphs —
            a precomputed `mapping.InferenceCost` (e.g.
            `convnet.cnn_inference_cost` for CNN deployments).
        mc_samples  : >0 routes this (silicon) model's requests through
            the per-request Monte-Carlo spec and serves the prediction
            of the summed votes; 0 serves one realization per request.
        warmup      : precompile the model's full bucket grid on every
            serving device now (otherwise call .warmup() before traffic).

        The model's dispatch spec is fixed here: every one of its
        micro-batches executes `pipe.run(x, spec[, keys])` with that one
        `InferenceSpec` — see `_Model.spec`.
        """
        if self._started:
            raise RuntimeError("register() before start()")
        if model_id in self._models:
            raise ValueError(f"model {model_id!r} already registered")
        if isinstance(model, (str, Path)):
            model = Deployment.load(model)
        if isinstance(model, Deployment):
            if layer_sizes is None and silicon_cost is None:
                layer_sizes = model.layer_sizes  # None for conv graphs
            pipe = model.pipeline()
        else:
            pipe = model
        silicon = pipe.physics is not None and not pipe.physics.is_noiseless
        if mc_samples and not silicon:
            raise ValueError("mc_samples needs a silicon-mode pipeline")
        if layer_sizes is not None and silicon_cost is not None:
            raise ValueError("pass layer_sizes OR silicon_cost, not both")
        cost = silicon_cost
        if layer_sizes is not None:
            if (int(layer_sizes[0]), int(layer_sizes[-1])) != \
                    (pipe.n_in, pipe.n_classes):
                raise ValueError(
                    f"layer_sizes {tuple(layer_sizes)} disagree with the "
                    f"pipeline ({pipe.n_in} -> {pipe.n_classes})"
                )
            plans = [
                mapping.plan_layer(int(n_out), int(n_in),
                                   pipe.head.bias_cells)
                for n_in, n_out in zip(layer_sizes[:-1], layer_sizes[1:])
            ]
            cost = mapping.model_inference_cost(
                plans, int(pipe.head.thresholds.shape[0])
            )
        if silicon:
            spec = (InferenceSpec(noise="per_request",
                                  mc_samples=int(mc_samples),
                                  reduction="sum")
                    if mc_samples else InferenceSpec(noise="per_request"))
        else:
            spec = InferenceSpec()
        self._models[model_id] = _Model(
            model_id=model_id, pipe=pipe, silicon=silicon,
            spec=spec, silicon_cost=cost,
        )
        if warmup:
            self._warm_model(self._models[model_id])

    def _warm_model(self, m: _Model) -> dict:
        # warm exactly the spec dispatch uses — every extra spec is
        # another XLA compile per bucket per device before traffic —
        # and with the SAME placement dispatch will stage with: jit
        # caches key on input sharding, so warming with a different
        # placement would never be hit and traffic would compile anyway
        times: dict = {}
        if self.fanout == "spmd":
            times.update(m.pipe.warmup(self.policy.max_batch,
                                       specs=(m.spec,),
                                       device=self._batch_sharding))
            return times
        for dev in self.devices:
            for (spec, bucket), s in m.pipe.warmup(
                self.policy.max_batch, specs=(m.spec,), device=dev
            ).items():
                times[(spec, bucket)] = times.get((spec, bucket), 0.0) + s
        return times

    def warmup(self) -> dict[str, dict]:
        """Precompile every (model, bucket, device) program variant.

        Returns {model_id: {(spec, bucket): seconds}} — per-program
        compile-cost attribution for serving startup (summed across
        devices for round-robin fan-out).
        """
        return {mid: self._warm_model(m)
                for mid, m in self._models.items()}

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> "PicBnnServer":
        """Validate the registry and launch the dispatch/completion
        threads; idempotent.  Returns self (context-manager entry)."""
        if self._started:
            return self
        if not self._models:
            raise RuntimeError("no models registered")
        for m in self._models.values():
            if m.pipe.max_bucket is None:
                continue
            # compare the BUCKET a full batch needs, not max_batch itself:
            # a non-power-of-two cap would pass a direct comparison and
            # then fail every full dispatch
            need = next_bucket(self.policy.max_batch, m.pipe.min_bucket)
            if need > m.pipe.max_bucket:
                raise ValueError(
                    f"policy.max_batch {self.policy.max_batch} needs "
                    f"bucket {need} > {m.model_id!r}'s pipeline "
                    f"max_bucket {m.pipe.max_bucket}"
                )
        self._started = True
        self._dispatch_t = threading.Thread(
            target=self._dispatch_loop, name="picbnn-dispatch", daemon=True
        )
        self._complete_t = threading.Thread(
            target=self._complete_loop, name="picbnn-complete", daemon=True
        )
        self._dispatch_t.start()
        self._complete_t.start()
        return self

    def close(self) -> None:
        """Drain admitted requests, complete in-flight batches, join."""
        if self._closed:
            return
        self._closed = True
        self._batcher.close()
        if self._started:
            self._dispatch_t.join()
            self._complete_t.join()
        else:
            # never started: fail anything queued so no handle hangs
            while True:
                got = self._batcher.next_batch(timeout=0)
                if got is None:
                    break
                self._fail_batch(got[0], got[1],
                                 RuntimeError("server closed before start"))

    def __enter__(self) -> "PicBnnServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def _admit(self, model_id: str, images, keys, single: bool,
               block: bool, timeout: Optional[float]):
        t_submit = time.perf_counter()
        m = self._models.get(model_id)
        if m is None:
            raise KeyError(f"unknown model {model_id!r}; registered: "
                           f"{sorted(self._models)}")
        if self._closed:
            raise RuntimeError("server is closed")
        x = np.asarray(images, np.float32)
        if single:
            x = x.reshape(1, -1) if x.ndim == 1 else x
        # reject bad shapes HERE: inside the dispatch thread they would
        # fail a whole coalesced batch of innocent neighbors
        if x.ndim != 2 or x.shape[1] != m.pipe.n_in:
            raise ValueError(
                f"expected image(s) [{'' if single else 'W, '}"
                f"{m.pipe.n_in}] for model {model_id!r}, got shape "
                f"{np.shape(images)}"
            )
        if m.silicon:
            if keys is None:
                raise ValueError(
                    f"model {model_id!r} is silicon-mode: each request "
                    "must carry its own PRNG key (key(s)=...)"
                )
            keys = np.asarray(keys, np.uint32)
            if single:
                keys = keys.reshape(1, -1) if keys.ndim == 1 else keys
            if keys.shape != (len(x), 2):
                raise ValueError(
                    f"keys must be raw uint32 [{len(x)}, 2] PRNG keys, "
                    f"got {keys.shape}"
                )
        elif keys is not None:
            raise ValueError(
                f"model {model_id!r} is noiseless: key(s)= not accepted"
            )
        with self._uid_lock:
            uid0 = self._uid
            self._uid += len(x)
        slab = _Slab(uid0, model_id, x, keys, t_submit)
        self._batcher.put(model_id, slab, size=slab.n, t_enqueue=t_submit,
                          block=block, timeout=timeout)
        return slab

    def submit(self, model_id: str, image, key=None, *,
               block: bool = True,
               timeout: Optional[float] = None) -> _Handle:
        """Enqueue one single-image request; returns a result handle.

        image : [n_in] in the ±1 domain (anything np.asarray-able).
        key   : per-request PRNG key (raw uint32 [2]) — REQUIRED for a
            silicon-mode model (it makes the served draw reproducible),
            rejected for a noiseless one.
        block/timeout : admission behavior when `max_queue` is bounded;
            block=False raises QueueFullError instead of waiting.
        """
        slab = self._admit(model_id, image, key, True, block, timeout)
        return _Handle(slab, self)

    def submit_many(self, model_id: str, images, keys=None, *,
                    block: bool = True,
                    timeout: Optional[float] = None) -> GroupHandle:
        """Enqueue a burst of single-image requests in one admission
        round; returns a GroupHandle over all of them.

        Each image is still an independent request (own uid, own key,
        free to be coalesced with other traffic and split across
        micro-batches) — but the burst is admitted, queued, and
        dispatched as ONE contiguous slab, so the per-request Python
        cost that caps a GIL-bound serving loop is paid per burst (a
        real RPC front door receives framed bursts anyway).
        `images`: [W, n_in]; `keys`: [W, 2] for silicon models.
        """
        slab = self._admit(model_id, images, keys, False, block, timeout)
        return GroupHandle(slab, self)

    def _fail_batch(self, model_id: str, spans, err: BaseException) -> None:
        n = sum(s.n for s in spans)
        batch = _Batch(model_id, n, 0, -1, time.perf_counter(),
                       np.full(n, time.perf_counter()))
        batch.error = err
        pos = 0
        for s in spans:
            s.lot.spans.append((batch, s.lo, pos, s.n))
            s.lot.placed += s.n
            pos += s.n
        with self._dispatch_cv:
            self._dispatch_cv.notify_all()
        batch.t_done = time.perf_counter()
        batch.event.set()
        with self._records_lock:
            self._records.append(batch)

    def _dispatch_loop(self) -> None:
        while True:
            got = self._batcher.next_batch()
            if got is None:
                break
            model_id, spans = got
            try:
                self._dispatch(self._models[model_id], spans)
            except BaseException as e:  # resolve, don't hang clients
                self._fail_batch(model_id, spans, e)
        with self._inflight_cond:
            self._inflight.append(None)  # completion sentinel
            self._inflight_cond.notify_all()

    def _dispatch(self, m: _Model, spans) -> None:
        t_dispatch = time.perf_counter()
        n = sum(s.n for s in spans)
        pipe = m.pipe
        bucket = next_bucket(n, pipe.min_bucket, pipe.max_bucket)
        # assemble straight into a bucket-sized host buffer with one
        # vectorized copy per span: every dispatch then presents the
        # exact operand shapes warmup() compiled for (a ragged [n, ...]
        # staging array would re-specialize the program per distinct n —
        # a fresh compile mid-traffic); pad rows are zeros (valid
        # ±1-domain garbage, dropped at readback)
        x = np.zeros((bucket, pipe.n_in), np.float32)
        keys = np.zeros((bucket, 2), np.uint32) if m.silicon else None
        t_subs = np.empty(n)
        placed = []
        pos = 0
        for s in spans:
            k, slab = s.n, s.lot
            x[pos:pos + k] = slab.x[s.lo:s.hi]
            if m.silicon:
                keys[pos:pos + k] = slab.keys[s.lo:s.hi]
            t_subs[pos:pos + k] = slab.t_submit
            placed.append((slab, s.lo, pos, k))
            pos += k
        if self.fanout == "spmd":
            dev_idx = -1
            target = self._batch_sharding
        else:
            dev_idx = self._next_dev
            self._next_dev = (self._next_dev + 1) % len(self.devices)
            target = self.devices[dev_idx]
        xd = jax.device_put(x, target)
        if m.silicon:
            kd = jax.device_put(keys, target)
            votes = pipe.run(xd, m.spec, keys=kd)
        else:
            votes = pipe.run(xd, m.spec)
        # jax dispatch is async: `votes` is a device future; hand it to
        # the completion thread and go assemble/stage the next batch
        batch = _Batch(m.model_id, n, bucket, dev_idx, t_dispatch, t_subs)
        for slab, lo, bpos, k in placed:
            slab.spans.append((batch, lo, bpos, k))
            slab.placed += k
        with self._dispatch_cv:
            self._dispatch_cv.notify_all()
        with self._inflight_cond:
            while len(self._inflight) >= self.policy.max_inflight:
                self._inflight_cond.wait()
            self._inflight.append((batch, votes))
            self._inflight_cond.notify_all()

    def _complete_loop(self) -> None:
        while True:
            with self._inflight_cond:
                while not self._inflight:
                    self._inflight_cond.wait()
                item = self._inflight.pop(0)
                self._inflight_cond.notify_all()
            if item is None:
                break
            batch, votes = item
            try:
                votes_np = np.asarray(votes)[:batch.n]  # sync + drop pad
                batch.votes = votes_np
                batch.preds = votes_np.argmax(-1)
            except BaseException as e:
                batch.error = e
            batch.t_done = time.perf_counter()
            batch.event.set()
            with self._records_lock:
                self._records.append(batch)
                if batch.error is None:
                    tot = self._totals.setdefault(
                        batch.model_id,
                        [0, 0, batch.t_dispatch, batch.t_done],
                    )
                    tot[0] += batch.n
                    tot[1] += 1
                    tot[2] = min(tot[2], batch.t_dispatch)
                    tot[3] = max(tot[3], batch.t_done)

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------
    def stats(self) -> ServerStats:
        """Aggregate ServerStats: lifetime-accurate counts/throughput
        (running totals), percentiles over the last `stats_window`
        completed batches."""
        with self._records_lock:
            records = [b for b in self._records if b.error is None]
            totals = {k: list(v) for k, v in self._totals.items()}
        if not totals:
            empty = latency_summary([])
            return ServerStats(0, 0, 0.0, 0.0, 0.0, 0.0,
                               self._batcher.high_water, empty, empty,
                               empty, {})

        def _summaries(rs):
            if not rs:
                e = latency_summary([])
                return e, e, e, 0.0
            lat = np.concatenate([b.t_done - b.t_submits for b in rs])
            que = np.concatenate([b.t_dispatch - b.t_submits for b in rs])
            svc = np.concatenate(
                [np.full(b.n, b.t_done - b.t_dispatch) for b in rs]
            )
            occ = float(np.mean([b.n / b.bucket for b in rs]))
            return (latency_summary(lat * 1e3), latency_summary(que * 1e3),
                    latency_summary(svc * 1e3), occ)

        n_req = sum(t[0] for t in totals.values())
        n_batches = sum(t[1] for t in totals.values())
        wall = (max(t[3] for t in totals.values())
                - min(t[2] for t in totals.values()))
        lat, que, svc, occ = _summaries(records)
        per_model = {}
        for mid, tot in totals.items():
            m = self._models[mid]
            mlat, mque, msvc, mocc = _summaries(
                [b for b in records if b.model_id == mid]
            )
            mwall = tot[3] - tot[2]
            si = (m.silicon_cost.inferences_per_s
                  if m.silicon_cost else None)
            rate = tot[0] / mwall if mwall > 0 else float("inf")
            per_model[mid] = ModelStats(
                model_id=mid,
                n_requests=tot[0],
                n_batches=tot[1],
                mean_batch=tot[0] / tot[1],
                mean_occupancy=mocc,
                inf_per_s=rate,
                latency=mlat,
                queue=mque,
                service=msvc,
                silicon_inf_per_s=si,
                vs_silicon=(rate / si if si else None),
            )
        return ServerStats(
            n_requests=n_req,
            n_batches=n_batches,
            wall_s=wall,
            inf_per_s=n_req / wall if wall > 0 else float("inf"),
            mean_batch=n_req / n_batches,
            mean_occupancy=occ,
            queue_high_water=self._batcher.high_water,
            latency=lat,
            queue=que,
            service=svc,
            per_model=per_model,
        )

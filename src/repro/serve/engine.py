"""Batched serving engine: request queue -> prefill -> decode loop.

Serving model: static batching with slot reuse.  Requests are grouped
into generation batches of `max_batch`; each batch is prefetched through
one prefill_step (padded to a common prompt length) and decoded step by
step with EOS short-circuiting.  The decode step is jitted once per
(batch, cache_len) shape — shapes are bucketed so recompilation is rare.

Continuous batching (per-slot positions and rolling admission) is the
documented extension: the cache layout (absolute `pos` entries per slot)
already supports it; the uniform-step engine keeps the dry-run and tests
deterministic.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serve.steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # [S] int32 token ids
    max_new_tokens: int = 32
    t_submit: Optional[float] = None  # stamped at generate() if unset


@dataclasses.dataclass
class Result:
    """One generation + per-request timing.

    queue_ms / service_ms / latency_ms are PER-REQUEST and share the
    serving-metrics vocabulary of the classification engine
    (serve/scheduler.py): queue = submit -> this request's batch started;
    service = batch start -> this request's LAST token (EOS-finished
    requests stop accruing service time while their batch keeps
    decoding).  prefill_ms / decode_ms remain as BATCH-level phase
    timings (every Result in a batch reports the same values — they
    describe the batch, not the request).
    """

    uid: int
    tokens: list
    prefill_ms: float  # batch-level: the shared prefill step
    decode_ms: float  # batch-level: the shared decode loop
    queue_ms: float = 0.0
    service_ms: float = 0.0

    @property
    def latency_ms(self) -> float:
        return self.queue_ms + self.service_ms


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_len: int = 256
    eos_id: int = 0
    greedy: bool = True
    temperature: float = 0.0
    pad_id: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self._prefills: dict = {}  # keyed by cache max_len (static arg)
        self._decode = make_decode_step(cfg, donate=True)

    def _prefill(self, params, batch, max_len: int):
        if max_len not in self._prefills:
            self._prefills[max_len] = make_prefill_step(
                self.cfg, max_len=max_len
            )
        return self._prefills[max_len](params, batch)

    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        s = max(len(r.prompt) for r in reqs)
        batch = np.full((len(reqs), s), self.ecfg.pad_id, np.int32)
        for i, r in enumerate(reqs):
            batch[i, s - len(r.prompt):] = r.prompt  # left-pad
        return batch

    def generate(self, requests: Iterable[Request]) -> list[Result]:
        reqs = list(requests)
        now = time.perf_counter()
        for r in reqs:  # batch-mode callers get queue time measured from
            if r.t_submit is None:  # entry; streaming callers pre-stamp
                r.t_submit = now
        out: list[Result] = []
        for i in range(0, len(reqs), self.ecfg.max_batch):
            out.extend(self._run_batch(reqs[i : i + self.ecfg.max_batch]))
        return out

    def _run_batch(self, reqs: list[Request]) -> list[Result]:
        prompts = self._pad_prompts(reqs)
        b, s = prompts.shape
        max_new = max(r.max_new_tokens for r in reqs)
        t0 = time.perf_counter()
        logits, cache = self._prefill(
            self.params, {"tokens": prompts}, max_len=s + max_new
        )
        logits.block_until_ready()
        t_prefill_done = time.perf_counter()
        prefill_ms = (t_prefill_done - t0) * 1e3

        tokens = np.argmax(np.asarray(logits), -1).astype(np.int32)
        generated = [[int(t)] for t in tokens]
        done = np.zeros(b, bool)
        # per-request completion stamps: a request's service time ends at
        # ITS last token, not at the end of the batch's decode loop
        t_finish = np.full(b, time.perf_counter())
        for i, r in enumerate(reqs):
            if tokens[i] == self.ecfg.eos_id or r.max_new_tokens <= 1:
                done[i] = True
        t1 = time.perf_counter()
        pos = s
        cur = tokens[:, None]
        for _ in range(max_new - 1 if not done.all() else 0):
            lg, cache = self._decode(
                self.params, cache, jnp.asarray(cur), jnp.int32(pos)
            )
            nxt = np.argmax(np.asarray(lg), -1).astype(np.int32)
            t_step = time.perf_counter()
            for i in range(b):
                if not done[i]:
                    generated[i].append(int(nxt[i]))
                    if nxt[i] == self.ecfg.eos_id:
                        done[i] = True
                    if len(generated[i]) >= reqs[i].max_new_tokens:
                        done[i] = True
                    t_finish[i] = t_step
            pos += 1
            cur = nxt[:, None]
            if done.all():
                break
        decode_ms = (time.perf_counter() - t1) * 1e3
        return [
            Result(
                uid=r.uid, tokens=generated[i], prefill_ms=prefill_ms,
                decode_ms=decode_ms,
                queue_ms=(t0 - r.t_submit) * 1e3,
                service_ms=(t_finish[i] - t0) * 1e3,
            )
            for i, r in enumerate(reqs)
        ]

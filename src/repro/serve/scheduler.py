"""Deadline-driven dynamic micro-batcher + shared serving metrics.

The coalescing half of the classification serving engine
(serve/picbnn.py), kept free of jax so its policy logic is unit-testable
with a fake clock:

  MicroBatcher — thread-safe multi-lane request queue.  One lane per
      model; a batch never mixes lanes (each lane dispatches into its own
      compiled pipeline).  `next_batch` returns a lane's requests when
      the lane reaches `max_batch` (a full bucket) OR its oldest request
      has waited `max_wait_us` (the latency deadline), whichever comes
      first — the classic dynamic-batching trade: batch occupancy vs
      added queueing latency.  Expected dispatch size at arrival rate
      lambda is therefore ~min(max_batch, lambda * max_wait), and the
      coalescing delay any request can suffer is bounded by max_wait
      (DESIGN.md §9 works the math).

  BatchingPolicy — the knobs, plus `max_queue` admission control
      (bounded total depth; QueueFullError on non-blocking overflow) and
      `max_inflight` (how many dispatched batches may be awaiting device
      completion — the host->device staging / compute overlap depth).

  LatencySummary / latency_summary — the one latency vocabulary shared
      by the classifier engine, the LM engine (serve/engine.py), and the
      load benchmark: per-request queue / service / total milliseconds
      summarized as mean/p50/p95/p99/max.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Optional

import numpy as np


class QueueFullError(RuntimeError):
    """Admission control rejected a request (queue at max_queue)."""


@dataclasses.dataclass(frozen=True)
class BatchingPolicy:
    """Knobs of the deadline-driven micro-batcher.

    max_batch   : dispatch as soon as a lane holds this many requests
                  (align with the pipeline's bucket grid / max_bucket so
                  dispatches land on precompiled buckets at occupancy 1).
    max_wait_us : dispatch a partial lane once its OLDEST request has
                  waited this long — the coalescing-latency deadline.
    max_queue   : total queued requests across lanes admitted before
                  submit blocks (or raises QueueFullError when
                  non-blocking).  0 = unbounded.
    max_inflight: dispatched-but-uncompleted batch depth; bounds device
                  queue growth while letting staging overlap compute.
    """

    max_batch: int = 256
    max_wait_us: float = 2000.0
    max_queue: int = 0
    max_inflight: int = 2

    @property
    def max_wait_s(self) -> float:
        """The dispatch deadline in seconds."""
        return self.max_wait_us * 1e-6


@dataclasses.dataclass(frozen=True)
class LatencySummary:
    """Percentile summary of a per-request millisecond series."""

    n: int
    mean_ms: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    max_ms: float

    def __str__(self) -> str:
        return (f"mean {self.mean_ms:.3f} / p50 {self.p50_ms:.3f} / "
                f"p95 {self.p95_ms:.3f} / p99 {self.p99_ms:.3f} / "
                f"max {self.max_ms:.3f} ms")


def latency_summary(values_ms) -> LatencySummary:
    """Count/mean/p50/p95/p99 (ms) of a latency sample array."""
    v = np.asarray(values_ms, np.float64)
    if v.size == 0:
        return LatencySummary(0, 0.0, 0.0, 0.0, 0.0, 0.0)
    return LatencySummary(
        n=int(v.size),
        mean_ms=float(v.mean()),
        p50_ms=float(np.percentile(v, 50)),
        p95_ms=float(np.percentile(v, 95)),
        p99_ms=float(np.percentile(v, 99)),
        max_ms=float(v.max()),
    )


@dataclasses.dataclass(frozen=True)
class Span:
    """A contiguous request range [lo, hi) of one enqueued lot."""

    t_enqueue: float
    lot: Any
    lo: int
    hi: int

    @property
    def n(self) -> int:
        """Requests covered by this span."""
        return self.hi - self.lo


class MicroBatcher:
    """Thread-safe deadline-driven request coalescer (multi-lane).

    Requests are enqueued as LOTS — an opaque object carrying `size`
    requests (a client burst is one lot; a single request is a lot of
    size 1).  Keeping lots intact until dispatch is what makes the hot
    path O(1) per *burst* instead of O(1) per request: no per-request
    queue nodes, no per-request lock traffic.  `next_batch` assembles up
    to `max_batch` requests as a list of `Span`s, splitting the last lot
    when it straddles the batch boundary (the remainder keeps its
    original enqueue time — its deadline clock must not reset).

    Dispatch rule per lane: full batch available, OR the lane's oldest
    request has waited `max_wait_us`, OR draining after close().

    `put` is called by any number of client threads, `next_batch` by the
    single dispatch thread.  `clock` is injectable (monotonic seconds)
    so deadline behavior is unit-testable without sleeping.
    """

    def __init__(self, policy: BatchingPolicy,
                 clock: Callable[[], float] = time.perf_counter):
        self.policy = policy
        self._clock = clock
        # lane -> deque of [t_enqueue, lot, lo, hi] (lo advances as the
        # dispatcher consumes the lot front-to-back)
        self._lanes: "collections.OrderedDict[str, collections.deque]" = (
            collections.OrderedDict()
        )
        self._sizes: dict[str, int] = {}  # per-lane queued request count
        self._cond = threading.Condition()
        self._closed = False
        self._depth = 0
        self.high_water = 0  # max total queued requests ever observed

    @property
    def depth(self) -> int:
        """Requests currently queued across all lanes."""
        return self._depth

    @property
    def closed(self) -> bool:
        """True once close() was called; puts are rejected after."""
        return self._closed

    def put(self, lane: str, lot: Any, size: int = 1,
            t_enqueue: Optional[float] = None, block: bool = True,
            timeout: Optional[float] = None) -> None:
        """Enqueue one lot of `size` requests.  Raises QueueFullError
        when bounded admission cannot take the whole lot (immediately if
        block=False, after `timeout` otherwise), RuntimeError after
        close()."""
        if size <= 0:
            raise ValueError(f"lot size must be >= 1, got {size}")
        p = self.policy
        if p.max_queue and size > p.max_queue:
            # can NEVER fit, even into an empty queue: reject now — a
            # blocking put would otherwise wait forever
            raise QueueFullError(
                f"lot of {size} exceeds max_queue {p.max_queue}"
            )
        with self._cond:
            if self._closed:
                raise RuntimeError("MicroBatcher is closed")
            if p.max_queue:
                deadline = (None if timeout is None
                            else self._clock() + timeout)
                while self._depth + size > p.max_queue:
                    if not block:
                        raise QueueFullError(
                            f"queue full ({self._depth}+{size}"
                            f">{p.max_queue})"
                        )
                    remaining = (None if deadline is None
                                 else deadline - self._clock())
                    if remaining is not None and remaining <= 0:
                        raise QueueFullError(
                            f"queue full ({self._depth}/{p.max_queue}) "
                            f"after {timeout}s"
                        )
                    self._cond.wait(remaining)
                    if self._closed:
                        raise RuntimeError("MicroBatcher is closed")
            dq = self._lanes.get(lane)
            if dq is None:
                dq = self._lanes[lane] = collections.deque()
                self._sizes[lane] = 0
            was = self._sizes[lane]
            dq.append([self._clock() if t_enqueue is None else t_enqueue,
                       lot, 0, size])
            self._sizes[lane] = was + size
            self._depth += size
            self.high_water = max(self.high_water, self._depth)
            # wake the dispatcher only when its wait target can change: a
            # lane starting its deadline clock, or crossing a full batch
            if was == 0 or (was < p.max_batch <= was + size):
                self._cond.notify_all()

    def _ready_lane(self, now: float):
        """(lane, deadline) of the dispatchable/oldest lane.

        (lane, None): dispatch NOW; (lane, t): sleep until t;
        (None, None): empty.  Priority order:

        1. lanes whose OLDEST request has passed its max_wait deadline
           (or draining after close), oldest head first — the bounded-
           delay contract: a flooded sibling lane that is perpetually
           full must not starve an expired partial batch;
        2. otherwise any full lane (costs no extra waiting, frees
           admission capacity fastest);
        3. otherwise sleep until the oldest head's deadline.
        """
        oldest_lane, oldest_t = None, None
        full_lane = None
        for lane, dq in self._lanes.items():
            if not dq:
                continue
            if oldest_t is None or dq[0][0] < oldest_t:
                oldest_lane, oldest_t = lane, dq[0][0]
            if full_lane is None and \
                    self._sizes[lane] >= self.policy.max_batch:
                full_lane = lane
        if oldest_lane is None:
            return None, None
        deadline = oldest_t + self.policy.max_wait_s
        if self._closed or now >= deadline:
            return oldest_lane, None
        if full_lane is not None:
            return full_lane, None
        return oldest_lane, deadline

    def next_batch(self, timeout: Optional[float] = None):
        """Block until a batch is due; return (lane, [Span, ...]) with
        span sizes summing to <= max_batch.

        Returns None when closed-and-drained, or when `timeout` elapses
        with nothing due (timeout=0 polls).
        """
        outer = None if timeout is None else self._clock() + timeout
        with self._cond:
            while True:
                now = self._clock()
                lane, deadline = self._ready_lane(now)
                if lane is not None and deadline is None:
                    dq = self._lanes[lane]
                    spans: list[Span] = []
                    room = self.policy.max_batch
                    while dq and room > 0:
                        entry = dq[0]
                        t, lot, lo, hi = entry
                        take = min(hi - lo, room)
                        spans.append(Span(t, lot, lo, lo + take))
                        room -= take
                        if lo + take == hi:
                            dq.popleft()
                        else:  # split: remainder keeps its deadline clock
                            entry[2] = lo + take
                    n = sum(s.n for s in spans)
                    self._sizes[lane] -= n
                    self._depth -= n
                    self._cond.notify_all()  # admission waiters
                    return lane, spans
                if lane is None and self._closed:
                    return None
                # sleep until the nearest wake-up: lane deadline, outer
                # timeout, or a notify
                targets = [t for t in (deadline, outer) if t is not None]
                if outer is not None and now >= outer:
                    return None
                self._cond.wait(
                    None if not targets else max(min(targets) - now, 0.0)
                )

    def close(self) -> None:
        """Stop admission; wake everyone.  Queued lots still drain."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

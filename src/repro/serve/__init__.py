"""Serving substrate: prefill/decode steps and the batched engine."""

from repro.serve.steps import (  # noqa: F401
    decode_step,
    greedy_sample,
    make_decode_step,
    make_prefill_step,
    prefill_step,
    temperature_sample,
)

"""Serving substrate: LM prefill/decode engine + the PiC-BNN
classification micro-batching server (serve/picbnn.py)."""

from repro.serve.scheduler import (  # noqa: F401
    BatchingPolicy,
    LatencySummary,
    MicroBatcher,
    QueueFullError,
    latency_summary,
)
from repro.serve.steps import (  # noqa: F401
    decode_step,
    greedy_sample,
    make_decode_step,
    make_prefill_step,
    prefill_step,
    temperature_sample,
)


def __getattr__(name):
    # PicBnnServer and friends import jax-heavy pipeline machinery;
    # resolve lazily so `from repro.serve import BatchingPolicy` stays
    # cheap for the LM path.
    if name in ("PicBnnServer", "ClassifyResult", "GroupHandle",
                "ServerStats", "ModelStats"):
        from repro.serve import picbnn

        return getattr(picbnn, name)
    raise AttributeError(name)

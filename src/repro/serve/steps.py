"""Jitted serving steps: prefill and single-token decode.

These are the functions the multi-pod dry-run lowers for the decode_32k /
long_500k / prefill_32k cells, and the building blocks of serve/engine.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


def prefill_step(cfg: ModelConfig, params, batch, max_len: int | None = None):
    """batch: {"tokens" [B,S]} or {"embeds" [B,S,D]} ->
    (last-token logits [B,V], cache sized max_len or S+64)."""
    if cfg.embeds_input:
        return M.prefill(params, cfg, embeds=batch["embeds"], max_len=max_len)
    return M.prefill(params, cfg, tokens=batch["tokens"], max_len=max_len)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One token for every sequence in the batch.

    tokens: [B, 1] int32 (or [B, 1, D] embeds); pos: scalar int32.
    Returns (logits [B, V], new_cache)."""
    return M.decode(params, cfg, cache, tokens, pos)


def greedy_sample(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(key, logits, temperature: float = 1.0):
    if temperature <= 0.0:
        return greedy_sample(logits)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def make_prefill_step(
    cfg: ModelConfig, donate: bool = False, max_len: int | None = None
):
    return jax.jit(functools.partial(prefill_step, cfg, max_len=max_len))


def make_decode_step(cfg: ModelConfig, donate: bool = True):
    return jax.jit(
        functools.partial(decode_step, cfg),
        donate_argnums=(1,) if donate else (),
    )

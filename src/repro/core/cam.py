"""Content-addressable memory (CAM) arrays with Hamming-distance-tolerant
approximate search — the storage/compute substrate of PiC-BNN.

A :class:`CAMArray` stores binary rows (bit-packed uint32 words).  A *search*
asserts a binary query on the searchlines of every row simultaneously and
returns, per row, a binary match decision: ``match <=> HD(row, query) <= T``
where ``T`` is the Hamming-distance tolerance threshold set by the analog
knobs (V_ref, V_eval, V_st; see core/device_model.py).

Semantics notes (paper Sec. IV):
  * per-bit match == XNOR == one binary multiplication;
  * the matchline voltage at sampling time encodes POPCOUNT;
  * the MLSA threshold implements the sign/majority nonlinearity;
  * batch-norm constants are materialized as extra always-match /
    always-mismatch cells appended to each row (``bias_cells``).

Two execution paths:
  * ``search`` / ``search_hd`` — pure-jnp reference semantics (the oracle);
  * kernels/cam_search.py — the Pallas TPU kernel with identical semantics
    (validated bit-exact in the noiseless limit by tests/test_kernels.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize, physics
from repro.core.device_model import (
    BANK_CONFIGS,
    AnalogParams,
    NoiseModel,
    NOISELESS,
    default_params,
    hd_threshold,
)


@dataclasses.dataclass(frozen=True)
class BankConfig:
    """One logical configuration of the 128-kbit PiC-BNN macro."""

    rows: int
    width: int  # bits per row

    def __post_init__(self):
        total = self.rows * self.width
        if total > 4 * 32 * 1024 * 8:  # > 128 kbit? (4 banks x 32 kbit)
            # Logical configs larger than the macro are tiled by the mapper;
            # the dataclass itself places no restriction.
            pass

    @property
    def capacity_bits(self) -> int:
        return self.rows * self.width


# The three logical configurations of the fabricated macro (Sec. III).
CONFIG_512x256 = BankConfig(512, 256)
CONFIG_1024x128 = BankConfig(1024, 128)
CONFIG_2048x64 = BankConfig(2048, 64)
LOGICAL_CONFIGS: Sequence[BankConfig] = (
    CONFIG_512x256,
    CONFIG_1024x128,
    CONFIG_2048x64,
)


def pick_bank_config(width_bits: int) -> BankConfig:
    """Smallest logical row width that fits `width_bits` (else widest)."""
    for cfg in sorted(LOGICAL_CONFIGS, key=lambda c: c.width):
        if cfg.width >= width_bits:
            return cfg
    return max(LOGICAL_CONFIGS, key=lambda c: c.width)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CAMArray:
    """A (logical) CAM array holding N binary rows of `n_bits` each.

    rows_packed : [N, ceil(n_bits/32)] uint32 — stored data D
    n_bits      : logical row width (excludes packing pad; pad bits are 0
                  in both query and rows so they never mismatch)
    """

    rows_packed: jax.Array
    n_bits: int

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        return (self.rows_packed,), (self.n_bits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(rows_packed=children[0], n_bits=aux[0])

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_bits(cls, bits) -> "CAMArray":
        """bits: [N, n_bits] in {0,1}."""
        bits = jnp.asarray(bits)
        return cls(rows_packed=binarize.pack_bits(bits), n_bits=bits.shape[-1])

    @classmethod
    def from_pm1(cls, values) -> "CAMArray":
        """values: [N, n_bits] in {-1,+1}."""
        return cls.from_bits(binarize.to_bits(jnp.asarray(values)))

    @property
    def n_rows(self) -> int:
        return self.rows_packed.shape[0]

    # -- search -------------------------------------------------------------
    def search_hd(self, query_packed) -> jax.Array:
        """Hamming distance of every row against query(s).

        query_packed: [..., Kw] uint32 -> returns [..., N] int32.
        (Silicon never exposes this quantity — it lives only on the ML as an
        analog voltage — but it is the reference semantics all binary match
        decisions derive from.)
        """
        return binarize.hamming_packed(
            query_packed[..., None, :], self.rows_packed
        )

    def search(
        self,
        query_packed,
        threshold,
        *,
        noise: NoiseModel = NOISELESS,
        params: Optional[AnalogParams] = None,
        key: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Approximate search: per-row binary match under HD tolerance.

        threshold  — integer/float HD tolerance T (already derived from the
                     analog knobs), scalar or broadcastable to [..., N].
        noise/key  — optional PVT noise: perturbs the *effective* per-row
                     threshold via the unified sampler
                     (physics.sample_search_thresholds) — ALL NoiseModel
                     sigmas apply, with nearest-Table-I-anchor knob
                     provenance for the vref/strobe terms.

        Returns uint8 [..., N]: 1 where HD(row, query) <= T_eff.
        """
        hd = self.search_hd(query_packed)
        t_eff = physics.sample_search_thresholds(
            key, threshold, noise, shape=hd.shape, params=params
        )
        return (hd.astype(jnp.float32) <= t_eff).astype(jnp.uint8)

    def search_knobs(
        self,
        query_packed,
        v_ref,
        v_eval,
        v_st,
        *,
        params: Optional[AnalogParams] = None,
        noise: NoiseModel = NOISELESS,
        key: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Search with the threshold derived from the analog knob voltages.

        Noise enters through the exact knob-space sampler
        (physics.sample_effective_threshold): the voltages themselves are
        perturbed and converted through `hd_threshold`, rather than the
        linearized per-pass deltas the schedule paths use.
        """
        params = params or default_params()
        if key is not None and noise.is_active:
            t = physics.sample_effective_threshold(
                key, params, noise, v_ref, v_eval, v_st, shape=(self.n_rows,)
            )
        else:
            t = hd_threshold(params, v_ref, v_eval, v_st)
        return self.search(query_packed, t)


def write_weights_with_bias(
    weights_pm1: jax.Array | np.ndarray,
    bias_counts: jax.Array | np.ndarray,
    bias_cells: int,
) -> CAMArray:
    """Build a CAM array realizing `W x + C` rows (paper Eq. 4).

    weights_pm1 : [N, K] in {-1,+1} — the binary weight rows W_j.
    bias_counts : [N] integer C_j in [-bias_cells, +bias_cells] — the folded
                  batch-norm constants.
    bias_cells  : number of extra CAM cells appended per row.

    Encoding of C_j with `bias_cells` extra cells (paper Sec. IV): the query
    drives logic '1' on every bias searchline; a bias cell storing '1'
    always matches (+1 contribution) and storing '0' always mismatches (-1).
    With p cells at '1' and (bias_cells - p) at '0' the row's dot product
    gains p - (bias_cells - p) = 2p - bias_cells, so p = (C_j+bias_cells)/2.
    C_j and bias_cells must have equal parity for an exact representation;
    we round C_j DOWN by one otherwise (1-LSB quantization, as in silicon
    where the cell count is fixed at array-write time).  Rounding down —
    rather than toward zero — is exactly decision-preserving for the
    dead-zone-free C_j that `bnn.fold` emits: with y + C on the odd grid,
    y + C > 0  <=>  y + (C - 1) >= 0, so the deployed CAM row makes the
    same sign decisions as the folded oracle on every input.  (Rounding a
    negative C toward zero instead would flip the decision at y = -C - 1.)
    """
    w = np.asarray(weights_pm1)
    c = np.asarray(bias_counts).astype(np.int64)
    n, _k = w.shape
    c = np.clip(c, -bias_cells, bias_cells)
    # parity fix: when (c + bias_cells) is odd, round c down by one.
    # After the clip above, c == -bias_cells implies even parity, so the
    # decrement never leaves the representable range.
    odd = (c + bias_cells) % 2 != 0
    c = np.where(odd, c - 1, c)
    p = (c + bias_cells) // 2  # cells storing '1'
    bias_bits = (np.arange(bias_cells)[None, :] < p[:, None]).astype(np.uint8)
    w_bits = (w > 0).astype(np.uint8)
    all_bits = np.concatenate([w_bits, bias_bits], axis=-1)
    return CAMArray.from_bits(jnp.asarray(all_bits))


def query_with_bias(x_pm1: jax.Array, bias_cells: int) -> jax.Array:
    """Pack an activation query, appending the all-'1' bias drive bits."""
    bits = binarize.to_bits(x_pm1)
    ones = jnp.ones((*bits.shape[:-1], bias_cells), jnp.uint8)
    return binarize.pack_bits(jnp.concatenate([bits, ones], axis=-1))

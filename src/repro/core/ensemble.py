"""Algorithm 1 — the paper's core contribution.

The output (fully connected) layer of a classification BNN is executed
multiple times with a *varying Hamming-distance tolerance threshold* (swept
through the analog knobs V_ref / V_eval / V_st).  Each pass produces one
binary output per class ("does class j match the feature vector within
HD <= T_t ?").  The final prediction is the per-class majority (vote count)
over the passes.

Why this works (law of large numbers, Sec. IV): with thresholds swept over
{0, 2, ..., 64}, class j collects ``votes_j = #{t : HD_j <= T_t + noise}``.
In the noiseless limit votes_j = #{t : T_t >= HD_j} is strictly monotone
decreasing in HD_j, so argmax(votes) == argmin(HD) == argmax(full-precision
logit) — the FP logit ranking is recovered from purely binary measurements.
Under analog noise each vote is a Bernoulli trial with success probability
sigmoid-like in (T_t - HD_j); summing over passes concentrates the estimate
(LLN), which is what lets the silicon skip ADC/TDC readout entirely.

Execution modes:
  faithful  — 33 sequential searches, per-pass PVT noise, per-pass knob
              voltages from the behavioural device model (the silicon flow).
  fused     — beyond-paper TPU optimization: HD is computed once per
              (query, row) and compared against all T in-register; the vote
              count is materialized directly.  Bit-exact equal to `faithful`
              in the noiseless limit (tests assert this); ~33x fewer array
              reads.  `votes_fused_noisy` is the silicon-conditioned twin:
              same HD-once amortization, thresholds sampled per pass from
              the unified physics (`core/physics.SearchPhysics`) — equal to
              `faithful` in distribution (tests assert mean/variance
              agreement), bit-equal to `fused` in the NOISELESS limit.
  kernel    — the Pallas implementation of `fused` (kernels/cam_search.py).

All noisy paths draw their effective thresholds from ONE sampler
(`SearchPhysics.sample`); no noise arithmetic lives in this module
(DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize
from repro.core.bnn import FoldedLayer
from repro.core.cam import CAMArray, query_with_bias, write_weights_with_bias
from repro.core.device_model import (
    AnalogParams,
    NoiseModel,
    NOISELESS,
    default_params,
    knob_schedule,
)
from repro.core.physics import SearchPhysics, achieved_sweep

# Algorithm 1 line 3: HD threshold sweep {0, 2, 4, ..., 64} -> 33 passes.
PAPER_THRESHOLDS = tuple(range(0, 65, 2))


@dataclasses.dataclass(frozen=True)
class EnsembleConfig:
    thresholds: Sequence[int] = PAPER_THRESHOLDS
    bias_cells: int = 64
    noise: NoiseModel = NOISELESS
    mode: str = "fused"  # faithful | fused | kernel
    # True: deploy the knob schedule's *achieved* calibrated tolerances
    # (what the analog knobs actually deliver, float) instead of the ideal
    # integer sweep — see build_head.
    calibrated: bool = False

    @property
    def n_passes(self) -> int:
        """Output-layer executions in the Algorithm-1 sweep."""
        return len(self.thresholds)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CAMEnsembleHead:
    """The deployed output layer: a CAM array + the threshold schedule.

    cam        : rows = classes; row = [binary weights | bias cells(C_j)]
    thresholds : int32 [n_passes] — HD tolerances swept by Algorithm 1.
                 NOTE: silicon thresholds apply to the *biased* row of width
                 n_in + bias_cells; a logical sweep {0,2,..,64} over logit
                 space maps to HD space via T_hd = (n_total - T_logit... see
                 `logit_sweep_to_hd`) — we store HD-space thresholds.
    """

    cam: CAMArray
    thresholds: jax.Array
    bias_cells: int

    def tree_flatten(self):
        """jax pytree protocol (heads pass through jit boundaries)."""
        return (self.cam, self.thresholds), (self.bias_cells,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """jax pytree protocol inverse of `tree_flatten`."""
        return cls(cam=children[0], thresholds=children[1], bias_cells=aux[0])

    @property
    def n_classes(self) -> int:
        """Classes = CAM rows of the head."""
        return self.cam.n_rows


def build_head(
    layer: FoldedLayer,
    cfg: EnsembleConfig,
) -> CAMEnsembleHead:
    """Write the folded output layer into a CAM ensemble head.

    Threshold-space note: Algorithm 1 sweeps HD tolerance {0, 2, ..., 64}.
    For a row of n_in + bias_cells total bits, the *informative* HD range
    (where class match decisions actually flip) is centered at the exact-
    majority point n_total/2 (dot = n - 2*HD, majority <=> HD <= n/2).  A
    raw absolute sweep {0..64} over a 192-bit row would never fire; we
    therefore center the paper's sweep on the majority point:
    ``T_t = n_total/2 - max(sweep)/2 + t`` — recovering exactly the paper's
    33 equispaced tolerance levels straddling the decision boundary.  This
    reading reproduces Fig. 5 (accuracy grows then saturates with pass
    count) and is recorded as an assumption in DESIGN.md.

    With ``cfg.calibrated`` the ideal integer sweep is replaced by the
    knob schedule's *achieved* tolerances (`physics.achieved_sweep`): the
    float thresholds the Table-I-calibrated analog knobs actually deliver,
    offset by the same centering.  Thresholds then carry float32 dtype;
    every consumer (fused/faithful/kernels) compares HD against them
    unchanged.
    """
    cam = write_weights_with_bias(layer.weights_pm1, layer.c, cfg.bias_cells)
    n_total = layer.n_in + cfg.bias_cells
    center = n_total // 2
    sweep = np.asarray(cfg.thresholds, np.int64)
    offset = center - sweep.max() // 2
    if cfg.calibrated:
        # achieved_sweep targets the equispaced linspace(0, max, P) —
        # the paper's sweep; anything else would silently deploy
        # unrelated operating points
        if not np.array_equal(
            sweep, np.linspace(0, sweep.max(), len(sweep)).round()
        ):
            raise ValueError(
                "calibrated=True supports only an equispaced threshold "
                f"sweep (the knob schedule targets it); got {sweep}"
            )
        t_hd = offset + achieved_sweep(len(sweep), int(sweep.max()))
        thresholds = jnp.asarray(t_hd, jnp.float32)
    else:
        thresholds = jnp.asarray(offset + sweep, jnp.int32)
    return CAMEnsembleHead(
        cam=cam,
        thresholds=thresholds,
        bias_cells=cfg.bias_cells,
    )


# ---------------------------------------------------------------------------
# Execution modes
# ---------------------------------------------------------------------------
def votes_faithful(
    head: CAMEnsembleHead,
    x_pm1: jax.Array,
    *,
    noise: NoiseModel = NOISELESS,
    key: Optional[jax.Array] = None,
    params: Optional[AnalogParams] = None,
    physics: Optional[SearchPhysics] = None,
) -> jax.Array:
    """The silicon flow: one search per threshold, per-pass PVT noise.

    x_pm1: [..., n_in] +-1 activations. Returns int32 votes [..., classes].

    The effective per-pass thresholds come from the unified sampler
    (`SearchPhysics.sample`) — ALL NoiseModel terms apply (sigma_hd per
    row; sigma_vref / sigma_tjitter pass-global through the Table-I knob
    schedule; temp_drift_hd systematic).  Pass `physics` to reuse a
    prebuilt bundle; otherwise one is built from (head, noise, params).
    """
    q = query_with_bias(x_pm1, head.bias_cells)
    hd = head.cam.search_hd(q).astype(jnp.float32)  # [..., C] (analog ML)
    phys = physics or SearchPhysics.for_head(head, noise, params)
    t_eff = phys.sample(key, batch_shape=hd.shape[:-1], n_rows=hd.shape[-1])
    votes = jnp.zeros(hd.shape, jnp.int32)
    for t in range(phys.n_passes):  # one search per pass, as in silicon
        votes = votes + (hd <= t_eff[t]).astype(jnp.int32)
    return votes


def votes_fused(head: CAMEnsembleHead, x_pm1: jax.Array) -> jax.Array:
    """Beyond-paper fused sweep: HD once, all thresholds in-register.

    The noiseless limit (the TPU compare is exact); bit-identical to
    votes_faithful(..., noise=NOISELESS).  For the silicon-conditioned
    twin with the same HD-once amortization see `votes_fused_noisy`.
    """
    q = query_with_bias(x_pm1, head.bias_cells)
    hd = head.cam.search_hd(q)  # [..., C]
    # votes_j = #{t : hd_j <= T_t}; thresholds sorted ascending ->
    # votes = n_passes - searchsorted(T, hd)
    t = head.thresholds
    return (hd[..., None] <= t).sum(-1).astype(jnp.int32)


def votes_fused_noisy(
    head: CAMEnsembleHead,
    x_pm1: jax.Array,
    *,
    key: Optional[jax.Array],
    noise: NoiseModel = NOISELESS,
    params: Optional[AnalogParams] = None,
    physics: Optional[SearchPhysics] = None,
) -> jax.Array:
    """Fused sweep under PVT noise: HD once, sampled thresholds [P, ..., C].

    Identical in distribution to `votes_faithful` (same unified sampler,
    same pass/row draw structure) and bit-identical to `votes_fused` in
    the NOISELESS limit — but vectorized over passes, so Monte-Carlo
    silicon-noise evaluation runs at fused speed (the pipeline's
    `votes_mc` builds on the same math).
    """
    q = query_with_bias(x_pm1, head.bias_cells)
    hd = head.cam.search_hd(q).astype(jnp.float32)  # [..., C]
    phys = physics or SearchPhysics.for_head(head, noise, params)
    t_eff = phys.sample(key, batch_shape=hd.shape[:-1], n_rows=hd.shape[-1])
    return (hd[None] <= t_eff).sum(0).astype(jnp.int32)


def votes_kernel(head: CAMEnsembleHead, x_pm1: jax.Array) -> jax.Array:
    """Pallas kernel path (interpret-mode on CPU). Same semantics as fused.

    Routed through the fused end-to-end pipeline kernel (kernels/fused_mlp)
    in its degenerate head-only form — one kernel, query in VMEM, votes
    out. The standalone cam_vote kernel remains for sub-head workloads.
    """
    from repro.kernels import fused_mlp  # local: kernels are optional deps

    q = query_with_bias(x_pm1, head.bias_cells)
    return fused_mlp.fused_mlp_votes(
        q, (), (), (), head.cam.rows_packed, head.thresholds,
        bias_cells=head.bias_cells, bq=128,
        interpret=jax.default_backend() != "tpu",
    )


def predict(
    head: CAMEnsembleHead,
    x_pm1: jax.Array,
    cfg: EnsembleConfig,
    *,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Algorithm 1 final prediction: per-class majority vote -> argmax."""
    if cfg.mode == "faithful":
        v = votes_faithful(head, x_pm1, noise=cfg.noise, key=key)
    elif cfg.mode == "fused":
        v = votes_fused(head, x_pm1)
    elif cfg.mode == "kernel":
        v = votes_kernel(head, x_pm1)
    else:
        raise ValueError(f"unknown ensemble mode {cfg.mode!r}")
    return jnp.argmax(v, axis=-1)


def topk_from_votes(votes: jax.Array, k: int) -> jax.Array:
    """Top-k classes by vote count (ties broken by class index)."""
    return jnp.argsort(-votes, axis=-1)[..., :k]


def accuracy_from_cumulative(
    cum_votes: jax.Array, labels, topk=(1, 2)
) -> dict[int, dict[str, float]]:
    """{p: {topK: acc}} from per-pass cumulative votes [P, B, C].

    The shared accuracy tail of `accuracy_sweep` and the fused-pipeline
    Fig.-5 path (cumulative votes via `sweep_from_votes`).
    """
    labels = jnp.asarray(labels)[:, None]
    out = {}
    for p in range(1, cum_votes.shape[0] + 1):
        order = jnp.argsort(-cum_votes[p - 1], axis=-1)
        out[p] = {
            f"top{k}": float((order[:, :k] == labels).any(-1).mean())
            for k in topk
        }
    return out


def sweep_from_votes(votes: jax.Array, n_passes: int) -> jax.Array:
    """Per-pass cumulative vote counts recovered from the fused total.

    NOISELESS-ONLY PRECONDITION (DESIGN.md §8): the reconstruction relies
    on the per-pass match indicators being a monotone staircase in the
    (sorted) threshold schedule — true only when every pass compares the
    same exact HD.  Under PVT noise the indicators are independent
    Bernoulli draws and the staircase identity breaks; silicon-noise
    truncated sweeps must use the sampled path
    (`pipeline.CompiledPipeline.cum_votes`) instead.  Callers feeding a
    noisy vote total here get silently wrong per-pass counts — guard at
    the call site (see benchmarks/accuracy.py).

    With the threshold schedule sorted ascending (as `build_head` emits
    it), pass t fires on class j iff t >= n_passes - votes_j in the
    noiseless limit; so the count after the first p passes is
    clip(votes_j - (n_passes - p), 0, p).  This lets Fig.-5-style
    truncated-sweep evaluations reuse ONE fused end-to-end pipeline pass
    instead of re-searching per pass count.

    votes: [..., C] int32 fused totals -> [n_passes, ..., C] int32.
    """
    p = jnp.arange(1, n_passes + 1).reshape((-1,) + (1,) * votes.ndim)
    return jnp.clip(votes[None] - (n_passes - p), 0, p).astype(jnp.int32)


def accuracy_sweep(
    head: CAMEnsembleHead,
    hidden_pm1: jax.Array,
    labels: jax.Array,
    cfg: EnsembleConfig,
    *,
    key: Optional[jax.Array] = None,
    topk=(1, 2),
) -> dict[int, dict[str, float]]:
    """Fig. 5 reproduction: accuracy as a function of the pass count.

    Evaluates Algorithm 1 truncated to the first p thresholds, for
    p = 1..n_passes.  Returns {n_passes: {"top1": ..., "top2": ...}}.
    """
    q = query_with_bias(hidden_pm1, head.bias_cells)
    hd = head.cam.search_hd(q).astype(jnp.float32)  # [B, C]
    phys = SearchPhysics.for_head(head, cfg.noise)
    t_eff = phys.sample(key, batch_shape=hd.shape[:-1], n_rows=hd.shape[-1])
    per_pass = (hd[None] <= t_eff).astype(jnp.int32)  # [P, B, C]
    cum = jnp.cumsum(per_pass, axis=0)  # votes after p passes
    return accuracy_from_cumulative(cum, labels, topk)

"""Algorithm 1 — the paper's core contribution.

The output (fully connected) layer of a classification BNN is executed
multiple times with a *varying Hamming-distance tolerance threshold* (swept
through the analog knobs V_ref / V_eval / V_st).  Each pass produces one
binary output per class ("does class j match the feature vector within
HD <= T_t ?").  The final prediction is the per-class majority (vote count)
over the passes.

Why this works (law of large numbers, Sec. IV): with thresholds swept over
{0, 2, ..., 64}, class j collects ``votes_j = #{t : HD_j <= T_t + noise}``.
In the noiseless limit votes_j = #{t : T_t >= HD_j} is strictly monotone
decreasing in HD_j, so argmax(votes) == argmin(HD) == argmax(full-precision
logit) — the FP logit ranking is recovered from purely binary measurements.
Under analog noise each vote is a Bernoulli trial with success probability
sigmoid-like in (T_t - HD_j); summing over passes concentrates the estimate
(LLN), which is what lets the silicon skip ADC/TDC readout entirely.

Three execution modes:
  faithful  — 33 sequential searches, per-pass PVT noise, per-pass knob
              voltages from the behavioural device model (the silicon flow).
  fused     — beyond-paper TPU optimization: HD is computed once per
              (query, row) and compared against all T in-register; the vote
              count is materialized directly.  Bit-exact equal to `faithful`
              in the noiseless limit (tests assert this); ~33x fewer array
              reads.
  kernel    — the Pallas implementation of `fused` (kernels/cam_search.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize
from repro.core.bnn import FoldedLayer
from repro.core.cam import CAMArray, query_with_bias, write_weights_with_bias
from repro.core.device_model import (
    AnalogParams,
    NoiseModel,
    NOISELESS,
    default_params,
    knob_schedule,
)

# Algorithm 1 line 3: HD threshold sweep {0, 2, 4, ..., 64} -> 33 passes.
PAPER_THRESHOLDS = tuple(range(0, 65, 2))


@dataclasses.dataclass(frozen=True)
class EnsembleConfig:
    thresholds: Sequence[int] = PAPER_THRESHOLDS
    bias_cells: int = 64
    noise: NoiseModel = NOISELESS
    mode: str = "fused"  # faithful | fused | kernel

    @property
    def n_passes(self) -> int:
        return len(self.thresholds)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CAMEnsembleHead:
    """The deployed output layer: a CAM array + the threshold schedule.

    cam        : rows = classes; row = [binary weights | bias cells(C_j)]
    thresholds : int32 [n_passes] — HD tolerances swept by Algorithm 1.
                 NOTE: silicon thresholds apply to the *biased* row of width
                 n_in + bias_cells; a logical sweep {0,2,..,64} over logit
                 space maps to HD space via T_hd = (n_total - T_logit... see
                 `logit_sweep_to_hd`) — we store HD-space thresholds.
    """

    cam: CAMArray
    thresholds: jax.Array
    bias_cells: int

    def tree_flatten(self):
        return (self.cam, self.thresholds), (self.bias_cells,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(cam=children[0], thresholds=children[1], bias_cells=aux[0])

    @property
    def n_classes(self) -> int:
        return self.cam.n_rows


def build_head(
    layer: FoldedLayer,
    cfg: EnsembleConfig,
) -> CAMEnsembleHead:
    """Write the folded output layer into a CAM ensemble head.

    Threshold-space note: Algorithm 1 sweeps HD tolerance {0, 2, ..., 64}.
    For a row of n_in + bias_cells total bits, the *informative* HD range
    (where class match decisions actually flip) is centered at the exact-
    majority point n_total/2 (dot = n - 2*HD, majority <=> HD <= n/2).  A
    raw absolute sweep {0..64} over a 192-bit row would never fire; we
    therefore center the paper's sweep on the majority point:
    ``T_t = n_total/2 - max(sweep)/2 + t`` — recovering exactly the paper's
    33 equispaced tolerance levels straddling the decision boundary.  This
    reading reproduces Fig. 5 (accuracy grows then saturates with pass
    count) and is recorded as an assumption in DESIGN.md.
    """
    cam = write_weights_with_bias(layer.weights_pm1, layer.c, cfg.bias_cells)
    n_total = layer.n_in + cfg.bias_cells
    center = n_total // 2
    sweep = np.asarray(cfg.thresholds, np.int64)
    t_hd = center - sweep.max() // 2 + sweep
    return CAMEnsembleHead(
        cam=cam,
        thresholds=jnp.asarray(t_hd, jnp.int32),
        bias_cells=cfg.bias_cells,
    )


# ---------------------------------------------------------------------------
# Execution modes
# ---------------------------------------------------------------------------
def votes_faithful(
    head: CAMEnsembleHead,
    x_pm1: jax.Array,
    *,
    noise: NoiseModel = NOISELESS,
    key: Optional[jax.Array] = None,
    params: Optional[AnalogParams] = None,
) -> jax.Array:
    """The silicon flow: one search per threshold, per-pass PVT noise.

    x_pm1: [..., n_in] +-1 activations. Returns int32 votes [..., classes].
    """
    q = query_with_bias(x_pm1, head.bias_cells)
    hd = head.cam.search_hd(q)  # [..., classes] (the analog ML state)
    n_passes = head.thresholds.shape[0]
    if key is None:
        keys = [None] * n_passes
    else:
        keys = list(jax.random.split(key, n_passes))

    votes = jnp.zeros(hd.shape, jnp.int32)
    for t in range(n_passes):
        t_eff = head.thresholds[t].astype(jnp.float32)
        if keys[t] is not None and (
            noise.sigma_hd or noise.sigma_vref or noise.sigma_tjitter
        ):
            t_eff = t_eff + noise.sigma_hd * jax.random.normal(
                keys[t], hd.shape
            ) + noise.temp_drift_hd
        votes = votes + (hd.astype(jnp.float32) <= t_eff).astype(jnp.int32)
    return votes


def votes_fused(head: CAMEnsembleHead, x_pm1: jax.Array) -> jax.Array:
    """Beyond-paper fused sweep: HD once, all thresholds in-register.

    Noiseless by construction (the TPU compare is exact); bit-identical to
    votes_faithful(..., noise=NOISELESS).
    """
    q = query_with_bias(x_pm1, head.bias_cells)
    hd = head.cam.search_hd(q)  # [..., C]
    # votes_j = #{t : hd_j <= T_t}; thresholds sorted ascending ->
    # votes = n_passes - searchsorted(T, hd)
    t = head.thresholds
    return (hd[..., None] <= t).sum(-1).astype(jnp.int32)


def votes_kernel(head: CAMEnsembleHead, x_pm1: jax.Array) -> jax.Array:
    """Pallas kernel path (interpret-mode on CPU). Same semantics as fused.

    Routed through the fused end-to-end pipeline kernel (kernels/fused_mlp)
    in its degenerate head-only form — one kernel, query in VMEM, votes
    out. The standalone cam_vote kernel remains for sub-head workloads.
    """
    from repro.kernels import fused_mlp  # local: kernels are optional deps

    q = query_with_bias(x_pm1, head.bias_cells)
    return fused_mlp.fused_mlp_votes(
        q, (), (), (), head.cam.rows_packed, head.thresholds,
        bias_cells=head.bias_cells, bq=128,
        interpret=jax.default_backend() != "tpu",
    )


def predict(
    head: CAMEnsembleHead,
    x_pm1: jax.Array,
    cfg: EnsembleConfig,
    *,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Algorithm 1 final prediction: per-class majority vote -> argmax."""
    if cfg.mode == "faithful":
        v = votes_faithful(head, x_pm1, noise=cfg.noise, key=key)
    elif cfg.mode == "fused":
        v = votes_fused(head, x_pm1)
    elif cfg.mode == "kernel":
        v = votes_kernel(head, x_pm1)
    else:
        raise ValueError(f"unknown ensemble mode {cfg.mode!r}")
    return jnp.argmax(v, axis=-1)


def topk_from_votes(votes: jax.Array, k: int) -> jax.Array:
    """Top-k classes by vote count (ties broken by class index)."""
    return jnp.argsort(-votes, axis=-1)[..., :k]


def accuracy_from_cumulative(
    cum_votes: jax.Array, labels, topk=(1, 2)
) -> dict[int, dict[str, float]]:
    """{p: {topK: acc}} from per-pass cumulative votes [P, B, C].

    The shared accuracy tail of `accuracy_sweep` and the fused-pipeline
    Fig.-5 path (cumulative votes via `sweep_from_votes`).
    """
    labels = jnp.asarray(labels)[:, None]
    out = {}
    for p in range(1, cum_votes.shape[0] + 1):
        order = jnp.argsort(-cum_votes[p - 1], axis=-1)
        out[p] = {
            f"top{k}": float((order[:, :k] == labels).any(-1).mean())
            for k in topk
        }
    return out


def sweep_from_votes(votes: jax.Array, n_passes: int) -> jax.Array:
    """Per-pass cumulative vote counts recovered from the fused total.

    With the threshold schedule sorted ascending (as `build_head` emits
    it), pass t fires on class j iff t >= n_passes - votes_j in the
    noiseless limit; so the count after the first p passes is
    clip(votes_j - (n_passes - p), 0, p).  This lets Fig.-5-style
    truncated-sweep evaluations reuse ONE fused end-to-end pipeline pass
    instead of re-searching per pass count.

    votes: [..., C] int32 fused totals -> [n_passes, ..., C] int32.
    """
    p = jnp.arange(1, n_passes + 1).reshape((-1,) + (1,) * votes.ndim)
    return jnp.clip(votes[None] - (n_passes - p), 0, p).astype(jnp.int32)


def accuracy_sweep(
    head: CAMEnsembleHead,
    hidden_pm1: jax.Array,
    labels: jax.Array,
    cfg: EnsembleConfig,
    *,
    key: Optional[jax.Array] = None,
    topk=(1, 2),
) -> dict[int, dict[str, float]]:
    """Fig. 5 reproduction: accuracy as a function of the pass count.

    Evaluates Algorithm 1 truncated to the first p thresholds, for
    p = 1..n_passes.  Returns {n_passes: {"top1": ..., "top2": ...}}.
    """
    q = query_with_bias(hidden_pm1, head.bias_cells)
    hd = head.cam.search_hd(q).astype(jnp.float32)  # [B, C]
    n_passes = head.thresholds.shape[0]
    if key is not None and (cfg.noise.sigma_hd or cfg.noise.sigma_tjitter):
        noise = cfg.noise.sigma_hd * jax.random.normal(
            key, (n_passes,) + hd.shape
        )
    else:
        noise = jnp.zeros((n_passes,) + hd.shape)
    t_eff = head.thresholds.astype(jnp.float32)[:, None, None] + noise
    per_pass = (hd[None] <= t_eff).astype(jnp.int32)  # [P, B, C]
    cum = jnp.cumsum(per_pass, axis=0)  # votes after p passes
    return accuracy_from_cumulative(cum, labels, topk)

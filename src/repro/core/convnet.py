"""End-to-end-binary CNN: training (sign-STE conv + batch-norm) and
deployment folding for the packed-domain conv pipeline.

The paper's central claim is *end-to-end* binarization: typical binary
CNNs keep the input layer in full precision, PiC-BNN binarizes
everything.  This module carries the conv analogue of `core/bnn.py`:

  * the INPUT layer is binary too — raw [0,1] pixels pass through a
    `binarize.InputEncoding` (thermometer by default) into `width`
    binary channels before the first conv;
  * conv layers train with latent real weights + sign-STE + per-channel
    batch norm, exactly the BinaryConnect recipe `bnn.py` uses for FC
    layers;
  * `fold_cnn` collapses each conv BN into an integer constant C_o
    (Eq. 3 per output channel) and emits `FoldedConvLayer` rows the
    packed-domain kernel (`kernels/fused_conv.py`) consumes, followed by
    folded FC layers for the MLP head — one flat list that
    `pipeline.compile_pipeline` compiles end to end.

Spatial semantics: VALID convolutions with integer stride (downsampling
is stride-2 convs, no pooling — pooling would need a majority/OR unit
outside the binary-matching machinery, stride-2 conv reuses it).
Deployment-side layout conventions (channel-packed NHWC words, per-
position word alignment at the flatten) are owned by
`kernels/fused_conv.py` and documented in DESIGN.md §10.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bnn import FoldedLayer, parity_adjust_c
from repro.core.binarize import InputEncoding, sign_ste

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One binary conv layer: k x k window, c_out filters, VALID, stride."""

    k: int
    c_out: int
    stride: int = 1

    def __post_init__(self):
        if self.k < 1 or self.c_out < 1 or self.stride < 1:
            raise ValueError(f"bad ConvSpec {self}")

    def out_side(self, side: int) -> int:
        """VALID output side for a square `side` input."""
        if side < self.k:
            raise ValueError(f"input side {side} < kernel {self.k}")
        return (side - self.k) // self.stride + 1


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """End-to-end-binary CNN hyperparameters.

    side      : square input image side (n_in = side * side raw pixels)
    encoding  : binary input layer ([0,1] pixel -> `encoding.width`
                binary channels; the paper's end-to-end claim)
    conv      : conv stack (VALID, strided)
    hidden    : FC widths between the flatten and the output layer
    n_classes : output classes (the CAM ensemble head rows)
    """

    side: int = 28
    encoding: InputEncoding = InputEncoding("thermometer", 8)
    conv: Sequence[ConvSpec] = (ConvSpec(3, 32, 2), ConvSpec(3, 32, 2))
    hidden: Sequence[int] = (128,)
    n_classes: int = 10
    bn_eps: float = 1e-5
    bn_momentum: float = 0.9
    bias_cells: int = 64

    @property
    def n_in(self) -> int:
        """Raw pixel count the pipeline/serving layer sees."""
        return self.side * self.side

    def feature_sides(self) -> list[int]:
        """Feature-map side after the input and after each conv layer."""
        sides = [self.side]
        for spec in self.conv:
            sides.append(spec.out_side(sides[-1]))
        return sides

    def feature_channels(self) -> list[int]:
        """Channel count entering each conv layer (+ the final one)."""
        return [self.encoding.width] + [s.c_out for s in self.conv]

    @property
    def flat_features(self) -> int:
        """Logical bits entering the MLP stage (final side^2 * c_out)."""
        return self.feature_sides()[-1] ** 2 * self.feature_channels()[-1]

    @property
    def fc_sizes(self) -> tuple[int, ...]:
        """(flat, *hidden, n_classes) — the MLP-stage layer sizes."""
        return (self.flat_features, *self.hidden, self.n_classes)


@dataclasses.dataclass(frozen=True)
class FoldedConvLayer:
    """Deployment form of one binary conv layer (Eq. 3 per channel).

    weights_pm1 : [c_out, k, k, c_in] ±1 filters (one CAM row per output
                  channel; row bits ordered tap-major (dy, dx, c) to
                  match the packed patch layout — DESIGN.md §10)
    c           : [c_out] integer BN constants, parity-adjusted so
                  sign(dot + C) has no dead zone (bnn.parity_adjust_c)
    stride      : spatial stride (VALID padding always)
    """

    weights_pm1: np.ndarray
    c: np.ndarray
    stride: int = 1

    @property
    def c_out(self) -> int:
        """Output channels (CAM rows / bits produced per position)."""
        return self.weights_pm1.shape[0]

    @property
    def k(self) -> int:
        """Square kernel side."""
        return self.weights_pm1.shape[1]

    @property
    def c_in(self) -> int:
        """Input channels per tap."""
        return self.weights_pm1.shape[3]

    @property
    def n_bits(self) -> int:
        """Logical dot width: k * k * c_in bits per patch."""
        return self.k * self.k * self.c_in


def init_cnn_params(key: jax.Array, cfg: CNNConfig,
                    dtype=jnp.float32) -> Params:
    """Glorot latent conv filters + FC weights, identity batch norm."""
    params: Params = {"conv": [], "fc": []}
    c_in = cfg.encoding.width
    for spec in cfg.conv:
        key, sub = jax.random.split(key)
        fan_in = spec.k * spec.k * c_in
        fan_out = spec.k * spec.k * spec.c_out
        lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
        params["conv"].append({
            "w": jax.random.uniform(
                sub, (spec.k, spec.k, c_in, spec.c_out), dtype,
                minval=-lim, maxval=lim,
            ),
            "gamma": jnp.ones((spec.c_out,), dtype),
            "beta": jnp.zeros((spec.c_out,), dtype),
            "mean": jnp.zeros((spec.c_out,), dtype),
            "var": jnp.ones((spec.c_out,), dtype),
        })
        c_in = spec.c_out
    sizes = cfg.fc_sizes
    for i in range(len(sizes) - 1):
        key, sub = jax.random.split(key)
        lim = float(np.sqrt(6.0 / (sizes[i] + sizes[i + 1])))
        params["fc"].append({
            "w": jax.random.uniform(
                sub, (sizes[i], sizes[i + 1]), dtype,
                minval=-lim, maxval=lim,
            ),
            "gamma": jnp.ones((sizes[i + 1],), dtype),
            "beta": jnp.zeros((sizes[i + 1],), dtype),
            "mean": jnp.zeros((sizes[i + 1],), dtype),
            "var": jnp.ones((sizes[i + 1],), dtype),
        })
    return params


def _bn(y, layer, eps, momentum, train: bool, axes):
    if train:
        mu = jnp.mean(y, axis=axes)
        var = jnp.var(y, axis=axes)
        stats = {
            "mean": momentum * layer["mean"] + (1 - momentum) * mu,
            "var": momentum * layer["var"] + (1 - momentum) * var,
        }
    else:
        mu, var = layer["mean"], layer["var"]
        stats = {}
    y_hat = (y - mu) / jnp.sqrt(var + eps)
    return layer["gamma"] * y_hat + layer["beta"], stats


def cnn_forward(params: Params, x01: jax.Array, cfg: CNNConfig, *,
                train: bool = False):
    """Forward pass on raw [0,1] pixels [B, side*side].

    The input layer is BINARY: pixels pass through `cfg.encoding` into
    ±1 channels before the first conv — no full-precision input layer
    anywhere.  Returns (logits, new_params) like `bnn.forward`: full-
    precision post-BN logits of the output layer (training criterion
    only; deployment replaces them with Algorithm-1 votes) and
    BN-stat-updated params when `train=True`.
    """
    b = x01.shape[0]
    h = cfg.encoding.encode_pm1(
        jnp.asarray(x01).reshape(b, cfg.side, cfg.side)
    )  # [B, H, W, E] ±1 — the binary input layer
    new_conv = []
    for layer, spec in zip(params["conv"], cfg.conv):
        wb = sign_ste(layer["w"])  # [k, k, c_in, c_out] ±1
        y = jax.lax.conv_general_dilated(
            h, wb, window_strides=(spec.stride, spec.stride),
            padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        y, stats = _bn(y, layer, cfg.bn_eps, cfg.bn_momentum, train,
                       axes=(0, 1, 2))
        new_conv.append({**layer, **stats})
        h = sign_ste(y)
    h = h.reshape(b, -1)  # NHWC flatten: logical (y, x, channel) order
    new_fc = []
    n_fc = len(params["fc"])
    for i, layer in enumerate(params["fc"]):
        wb = sign_ste(layer["w"])
        y = h @ wb
        y, stats = _bn(y, layer, cfg.bn_eps, cfg.bn_momentum, train,
                       axes=(0,))
        new_fc.append({**layer, **stats})
        if i < n_fc - 1:
            h = sign_ste(y)
    return y, {"conv": new_conv, "fc": new_fc}


def cnn_loss(params: Params, x01, labels, cfg: CNNConfig):
    """Cross-entropy on the (training-only) full-precision logits."""
    logits, new_params = cnn_forward(params, x01, cfg, train=True)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return nll, new_params


def _fold_bn(w_rows: np.ndarray, layer, eps: float, n_bits: int,
             bias_cells: int):
    """Shared Eq.-3 BN collapse: ±1 rows [out, bits] + BN -> (rows, C).

    Same algebra as `bnn.fold`: flip rows where gamma < 0, then
    C = round(beta*sigma/|gamma| - mu'), parity-adjusted against the
    dot width so sign(dot + C) never hits the dead zone.
    """
    gamma = np.asarray(layer["gamma"], np.float64)
    beta = np.asarray(layer["beta"], np.float64)
    mu = np.asarray(layer["mean"], np.float64)
    sigma = np.sqrt(np.asarray(layer["var"], np.float64) + eps)
    flip = gamma < 0
    w_rows = np.where(flip.reshape((-1,) + (1,) * (w_rows.ndim - 1)),
                      -w_rows, w_rows)
    thresh = mu - beta * sigma / np.where(gamma == 0, 1e-12, gamma)
    thresh = np.where(flip, -thresh, thresh)
    c = parity_adjust_c(np.round(-thresh).astype(np.int64), n_bits,
                        bias_cells)
    return w_rows.astype(np.int8), c


def fold_cnn(params: Params, cfg: CNNConfig) -> list:
    """Collapse trained BN into integer constants per channel/neuron.

    Returns [FoldedConvLayer, ..., FoldedLayer, ...] — the conv stack
    followed by the MLP stage, the flat graph
    `pipeline.compile_pipeline` accepts.  Conv filters are emitted as
    CAM rows [c_out, k, k, c_in] (tap-major bit order); the first FC
    layer's n_in is `cfg.flat_features` in NHWC flatten order, matching
    the training-time reshape bit for bit.
    """
    folded: list = []
    for layer, spec in zip(params["conv"], cfg.conv):
        w = np.asarray(jnp.sign(layer["w"]))
        w = np.where(w == 0, 1.0, w)  # sign(0) -> +1, paper's '1' coding
        # [k, k, c_in, c_out] -> rows [c_out, k, k, c_in]
        w = np.transpose(w, (3, 0, 1, 2))
        n_bits = spec.k * spec.k * w.shape[3]
        w, c = _fold_bn(w, layer, cfg.bn_eps, n_bits, cfg.bias_cells)
        folded.append(FoldedConvLayer(weights_pm1=w, c=c,
                                      stride=spec.stride))
    for layer in params["fc"]:
        w = np.asarray(jnp.sign(layer["w"]))
        w = np.where(w == 0, 1.0, w).T  # [out, in]
        w, c = _fold_bn(w, layer, cfg.bn_eps, w.shape[1], cfg.bias_cells)
        folded.append(FoldedLayer(weights_pm1=w, c=c))
    return folded


def train_cnn(
    key: jax.Array,
    cfg: CNNConfig,
    train_x: np.ndarray,
    train_y: np.ndarray,
    *,
    epochs: int = 6,
    batch: int = 128,
    lr: float = 1e-3,
    verbose: bool = False,
) -> Params:
    """Adam on latent weights with [-1, 1] latent clipping.

    `train_x` is RAW [0,1] pixels [N, side*side] — the binary input
    encoding happens inside the forward pass (the whole point of the
    end-to-end-binary workload).  Same BinaryConnect recipe as
    `bnn.train_mlp`; BN running stats ride back through the loss aux.
    """
    params = init_cnn_params(key, cfg)
    flat, treedef = jax.tree_util.tree_flatten(params)
    m = [jnp.zeros_like(x) for x in flat]
    v = [jnp.zeros_like(x) for x in flat]

    grad_fn = jax.jit(
        lambda p, x, y: jax.grad(cnn_loss, has_aux=True)(p, x, y, cfg)
    )

    @jax.jit
    def adam_update(flat, m, v, gflat, t):
        b1, b2, eps = 0.9, 0.999, 1e-8
        out_f, out_m, out_v = [], [], []
        for x, mi, vi, g in zip(flat, m, v, gflat):
            mi = b1 * mi + (1 - b1) * g
            vi = b2 * vi + (1 - b2) * g * g
            mh = mi / (1 - b1 ** t)
            vh = vi / (1 - b2 ** t)
            out_f.append(x - lr * mh / (jnp.sqrt(vh) + eps))
            out_m.append(mi)
            out_v.append(vi)
        return out_f, out_m, out_v

    n = train_x.shape[0]
    steps = max(n // batch, 1)
    t = 0
    rng = np.random.default_rng(0)
    for epoch in range(epochs):
        perm = rng.permutation(n)
        for s in range(steps):
            idx = perm[s * batch: (s + 1) * batch]
            grads, params = grad_fn(
                params, jnp.asarray(train_x[idx]), jnp.asarray(train_y[idx])
            )
            gflat = jax.tree_util.tree_leaves(grads)
            flat = jax.tree_util.tree_leaves(params)
            t += 1
            flat, m, v = adam_update(flat, m, v, gflat, t)
            params = jax.tree_util.tree_unflatten(treedef, flat)
            # clip ONLY the latent weights to [-1, 1] (BinaryConnect);
            # BN params and running stats must stay free — clipping them
            # would pin the running variance at 1 and corrupt every
            # eval/fold that consumes the stats (train_mlp's contract)
            for layer in params["conv"] + params["fc"]:
                layer["w"] = jnp.clip(layer["w"], -1.0, 1.0)
        if verbose:
            logits, _ = cnn_forward(params, jnp.asarray(train_x[:1024]), cfg)
            acc = float(
                (jnp.argmax(logits, -1) == jnp.asarray(train_y[:1024])).mean()
            )
            print(f"  epoch {epoch + 1}/{epochs}: train-acc(sample)={acc:.4f}")
    return params


def eval_cnn_accuracy(params: Params, cfg: CNNConfig, x01, y,
                      topk=(1,)) -> dict:
    """Top-k accuracy of the full-precision-logit software path."""
    logits, _ = cnn_forward(params, jnp.asarray(x01), cfg)
    order = jnp.argsort(-logits, axis=-1)
    yj = jnp.asarray(y)[:, None]
    return {
        f"top{k}": float((order[:, :k] == yj).any(-1).mean()) for k in topk
    }


def cnn_inference_cost(cfg: CNNConfig, n_output_passes: int = 33):
    """Table-II-style silicon cost of one CNN inference on the macro.

    Each conv layer maps its filters onto a CAM tile plan
    (`mapping.plan_layer` with row width k*k*c_in + bias cells) and is
    searched once per output position; FC layers query once; the output
    layer sweeps `n_output_passes` thresholds.  This is what the serving
    registry reports as the silicon-equivalent throughput for CNN
    models (`PicBnnServer.register(silicon_cost=...)`).
    """
    from repro.core import mapping

    sides = cfg.feature_sides()
    chans = cfg.feature_channels()
    plans, queries = [], []
    for spec, c_in, s_out in zip(cfg.conv, chans[:-1], sides[1:]):
        plans.append(mapping.plan_layer(
            spec.c_out, spec.k * spec.k * c_in, cfg.bias_cells
        ))
        queries.append(s_out * s_out)
    sizes = cfg.fc_sizes
    for n_in, n_out in zip(sizes[:-1], sizes[1:]):
        plans.append(mapping.plan_layer(n_out, n_in, cfg.bias_cells))
        queries.append(1)
    return mapping.model_inference_cost(
        plans, n_output_passes, layer_queries=queries
    )


def random_folded_cnn(cfg: CNNConfig, seed: int = 0, cmax: int = 24) -> list:
    """An untrained deployed CNN with fold-style parity-adjusted C.

    The shape-and-semantics twin of the benchmarks' `random_folded` MLP
    helper: random ±1 filters/weights with valid dead-zone-free
    constants, for bit-exactness tests and throughput benchmarks that
    don't need a trained model.
    """
    rng = np.random.default_rng(seed)
    folded: list = []
    c_in = cfg.encoding.width
    for spec in cfg.conv:
        n_bits = spec.k * spec.k * c_in
        c = parity_adjust_c(
            rng.integers(-cmax, cmax + 1, spec.c_out), n_bits,
            cfg.bias_cells,
        )
        folded.append(FoldedConvLayer(
            weights_pm1=rng.choice(
                [-1, 1], (spec.c_out, spec.k, spec.k, c_in)
            ).astype(np.int8),
            c=c,
            stride=spec.stride,
        ))
        c_in = spec.c_out
    sizes = cfg.fc_sizes
    for i in range(len(sizes) - 1):
        c = parity_adjust_c(
            rng.integers(-cmax, cmax + 1, sizes[i + 1]), sizes[i],
            cfg.bias_cells,
        )
        folded.append(FoldedLayer(
            weights_pm1=rng.choice(
                [-1, 1], (sizes[i + 1], sizes[i])
            ).astype(np.int8),
            c=c,
        ))
    return folded

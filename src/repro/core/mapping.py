"""Layer -> CAM-bank mapping and the silicon throughput/energy model.

The fabricated macro is 128 kbit in four 32-kbit banks, logically
configurable as 512x256 / 1024x128 / 2048x64 (rows x row-bits).  A search
evaluates every row of the active configuration in ONE clock cycle
(25 MHz), so a binary FC layer of (in <= row_bits, out <= rows) executes in
a single cycle (paper Sec. V-B: "processing binary fully connected layers
of up to 64x2048, 128x1024, or 256x512 per clock cycle").

Layers that exceed one configuration are tiled:
  * output tiling (rows): extra row tiles cost extra cycles (or extra
    macros at scale) — trivially exact.
  * input tiling (row bits): the silicon cannot sum matchline charge across
    banks, so a row wider than 256 bits must be split into column tiles.
    The paper does not specify the recombination for its 784-bit MNIST
    input layer; we implement BOTH readings and quantify the gap:
      - ``exact``        — per-tile HDs accumulated digitally, sign at the
                           end (Eq. 3 semantics; needs a small popcount
                           adder tree at the periphery);
      - ``hierarchical`` — per-tile MAJ decisions recombined by a second
                           CAM majority pass over the tile votes (strictly
                           end-to-end binary, zero digital arithmetic —
                           the reading most consistent with the paper's
                           no-auxiliary-digital-units claim).
    DESIGN.md records this as a resolved ambiguity; benchmarks/accuracy.py
    reports MNIST accuracy under both.

The cycle/energy model grounds benchmarks/table2.py in the measured silicon
figures (25 MHz, 0.8 mW, 560 K inf/s, 703 M inf/s/W).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize
from repro.core.bnn import FoldedLayer
from repro.core.cam import CAMArray, write_weights_with_bias
from repro.core.device_model import BANK_CONFIGS, EnergyModel


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """How one folded FC layer maps onto CAM logical configurations."""

    rows: int  # logical rows per tile (config rows)
    row_bits: int  # logical row width (config bits)
    n_row_tiles: int  # output-dim tiles
    n_col_tiles: int  # input-dim tiles
    bias_cells: int  # appended to the LAST column tile
    cycles_per_query: int  # searches to evaluate the full layer once

    @property
    def n_tiles(self) -> int:
        """CAM tiles the layer occupies (row tiles x column tiles)."""
        return self.n_row_tiles * self.n_col_tiles


def plan_layer(
    n_out: int,
    n_in: int,
    bias_cells: int,
    configs: Sequence[tuple[int, int]] = BANK_CONFIGS,
) -> TilePlan:
    """Choose the logical config minimizing cycles (then energy) for a layer."""
    best: Optional[TilePlan] = None
    for rows, bits in configs:
        n_col = math.ceil((n_in + bias_cells) / bits)
        n_row = math.ceil(n_out / rows)
        cycles = n_col * n_row
        plan = TilePlan(
            rows=rows,
            row_bits=bits,
            n_row_tiles=n_row,
            n_col_tiles=n_col,
            bias_cells=bias_cells,
            cycles_per_query=cycles,
        )
        if best is None or plan.cycles_per_query < best.cycles_per_query:
            best = plan
    assert best is not None
    return best


@dataclasses.dataclass
class MappedLayer:
    """A folded layer written into (possibly multiple) CAM tiles.

    col_tiles : list over input tiles of CAMArray [n_out_padded, tile_bits];
                the last tile carries the bias cells.
    tile_bits : logical bits per column tile (before bias cells).
    """

    plan: TilePlan
    col_tiles: list[CAMArray]
    col_widths: list[int]  # logical (unpadded) weight bits per tile
    n_out: int
    n_in: int
    c: np.ndarray  # [n_out] folded BN constants


def map_layer(layer: FoldedLayer, bias_cells: int = 64) -> MappedLayer:
    """Tile a folded layer onto CAM arrays per its TilePlan."""
    plan = plan_layer(layer.n_out, layer.n_in, bias_cells)
    w = np.asarray(layer.weights_pm1)
    tiles: list[CAMArray] = []
    widths: list[int] = []
    step = plan.row_bits
    # Column tiles over the input dimension; bias cells ride on the last.
    n_weight_cols = math.ceil(layer.n_in / step)
    for ci in range(n_weight_cols):
        lo, hi = ci * step, min((ci + 1) * step, layer.n_in)
        chunk = w[:, lo:hi]
        if ci == n_weight_cols - 1 and (hi - lo) + bias_cells <= step:
            cam = write_weights_with_bias(
                chunk, layer.c, bias_cells
            )
            widths.append(hi - lo + bias_cells)
        else:
            cam = CAMArray.from_pm1(jnp.asarray(chunk.astype(np.float32)))
            widths.append(hi - lo)
        tiles.append(cam)
    if len(widths) == n_weight_cols and widths[-1] == (
        layer.n_in - (n_weight_cols - 1) * step
    ):
        # bias did not fit on the last weight tile -> dedicated bias tile
        cam = write_weights_with_bias(
            np.zeros((layer.n_out, 0), np.int8), layer.c, bias_cells
        )
        tiles.append(cam)
        widths.append(bias_cells)
    return MappedLayer(
        plan=plan,
        col_tiles=tiles,
        col_widths=widths,
        n_out=layer.n_out,
        n_in=layer.n_in,
        c=np.asarray(layer.c),
    )


def _tile_queries(mapped: MappedLayer, x_pm1: jax.Array) -> list[jax.Array]:
    """Split + pack the query into per-column-tile searchline patterns."""
    step = mapped.plan.row_bits
    qs = []
    consumed = 0
    for cam, width in zip(mapped.col_tiles, mapped.col_widths):
        n_weight_bits = min(width, mapped.n_in - consumed)
        chunk = x_pm1[..., consumed : consumed + max(n_weight_bits, 0)]
        consumed += max(n_weight_bits, 0)
        bits = binarize.to_bits(chunk)
        n_bias = width - n_weight_bits
        if n_bias > 0:  # bias searchlines always driven to '1'
            ones = jnp.ones((*bits.shape[:-1], n_bias), jnp.uint8)
            bits = jnp.concatenate([bits, ones], axis=-1)
        qs.append(binarize.pack_bits(bits))
    return qs


def layer_forward(
    mapped: MappedLayer,
    x_pm1: jax.Array,
    mode: Literal["exact", "hierarchical"] = "exact",
) -> jax.Array:
    """Evaluate sign(Wx + C) through the CAM tiles.

    exact        — digital accumulation of per-tile dots (Eq. 3 oracle).
    hierarchical — strictly-binary: per-tile MAJ votes recombined by a
                   majority over tiles (one extra CAM pass in silicon).
    Returns +-1 activations [..., n_out].
    """
    qs = _tile_queries(mapped, x_pm1)
    if mode == "exact":
        total_dot = None
        for cam, q, width in zip(mapped.col_tiles, qs, mapped.col_widths):
            hd = cam.search_hd(q)
            dot = width - 2 * hd  # +-1 dot incl. bias cells on last tile
            total_dot = dot if total_dot is None else total_dot + dot
        return jnp.where(total_dot >= 0, 1.0, -1.0)
    elif mode == "hierarchical":
        votes = None
        for cam, q, width in zip(mapped.col_tiles, qs, mapped.col_widths):
            hd = cam.search_hd(q)
            maj = (2 * hd <= width).astype(jnp.int32)  # tile-level MAJ
            votes = maj if votes is None else votes + maj
        n_tiles = len(mapped.col_tiles)
        return jnp.where(2 * votes >= n_tiles, 1.0, -1.0)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# Silicon performance model (Table II)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InferenceCost:
    cycles: int
    searches: int
    binary_ops: int  # XNOR+accumulate ops actually performed
    energy_j: float
    latency_s: float

    @property
    def inferences_per_s(self) -> float:
        """Throughput implied by the modeled latency."""
        return 1.0 / self.latency_s if self.latency_s else float("inf")


def model_inference_cost(
    layer_plans: Sequence[TilePlan],
    n_output_passes: int,
    energy: EnergyModel = EnergyModel(),
    batch_per_tune: int = 8192,
    layer_queries: Optional[Sequence[int]] = None,
) -> InferenceCost:
    """Cycle/energy model of one inference (Algorithm 1 flow).

    Hidden layers execute once; the output layer executes `n_output_passes`
    times (the threshold sweep).  Voltage re-tuning costs `tuning_cycles`
    but is amortized over `batch_per_tune` images (paper Sec. V-B batching;
    the default reproduces the paper's 560 K inf/s at 25 MHz, implying
    ~10 cycles of amortized tuning per inference).

    layer_queries : optional per-layer query multiplicity (default 1 per
    layer).  A conv layer maps onto the CAM as one filter-rows array
    searched once PER OUTPUT POSITION, so its plan executes
    out_side**2 times per inference — `convnet.cnn_inference_cost`
    passes those counts here.

    Energy basis: the macro draws its measured 0.8 mW whenever active, so
    E = P x latency (matches Table II's 703 M inf/s/W == 1.43 nJ/inf);
    the per-search active-fraction numbers remain available through
    EnergyModel.search_energy_j for sub-macro analyses.
    """
    if layer_queries is None:
        layer_queries = [1] * len(layer_plans)
    if len(layer_queries) != len(layer_plans):
        raise ValueError("layer_queries/layer_plans length mismatch")
    cycles = 0
    searches = 0
    ops = 0
    for i, (plan, nq) in enumerate(zip(layer_plans, layer_queries)):
        passes = (n_output_passes if i == len(layer_plans) - 1 else 1) * nq
        cycles += plan.cycles_per_query * passes
        searches += plan.n_tiles * passes
        ops += (
            energy.ops_per_search(plan.rows, plan.row_bits)
            * plan.n_tiles * passes
        )
    # amortized re-tuning: one tune per threshold, spread over the batch
    tune_cycles = energy.tuning_cycles * n_output_passes / batch_per_tune
    cycles += int(math.ceil(tune_cycles))
    latency = cycles / energy.clock_hz
    e = energy.power_w * latency
    return InferenceCost(
        cycles=cycles,
        searches=searches,
        binary_ops=ops,
        energy_j=e,
        latency_s=latency,
    )

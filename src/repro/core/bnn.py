"""Binary MLP: training (latent weights + sign-STE + batch-norm) and
deployment (BN folding into CAM bias cells) — paper Eqs. (1)-(4).

Training follows BinaryConnect/XNOR-Net practice:
  * latent real-valued weights, binarized with sign() on the forward pass,
    straight-through (clipped) estimator on the backward pass;
  * activations binarized the same way between layers;
  * batch normalization after every binary dot product (Eq. 2) — essential
    so activations use both +1 and -1 (paper Sec. II-B);
  * cross-entropy on full-precision logits of the *output* dot product
    (training only; the deployed network never computes these logits —
    that is exactly what Algorithm 1 replaces).

Deployment (`fold`) collapses each BN into an integer constant C_j
(Eq. 3) and emits binary weight rows + C_j for the CAM mapper:

    BN(y) >= 0  <=>  gamma * (y - mu)/sigma + beta >= 0
                <=>  sign(gamma) * y >= sign(gamma) * (mu - beta*sigma/gamma)
   flip rows where gamma < 0 (W'_j = -W_j makes y' = -y), then
    X^{l+1} = sign(y' + C_j),   C_j = round(beta*sigma/|gamma| - mu')

so the deployed layer is exactly Eq. (3): sign(POPCOUNT(XNOR(W,x)) + C).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binarize import from_bits, sign_ste


Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    """Binary MLP hyperparameters (paper Sec. V-A models by default)."""

    layer_sizes: Sequence[int] = (784, 128, 10)  # MNIST: 784 -> 128 -> 10
    bn_eps: float = 1e-5
    bn_momentum: float = 0.9
    # number of CAM bias cells appended per row at deployment; bounds |C_j|
    bias_cells: int = 64

    @property
    def n_layers(self) -> int:
        """Number of weight layers (FC transitions)."""
        return len(self.layer_sizes) - 1


def init_params(key: jax.Array, cfg: MLPConfig, dtype=jnp.float32) -> Params:
    """Glorot-uniform latent weights + identity BN, running stats at (0,1)."""
    params: Params = {"layers": []}
    for i in range(cfg.n_layers):
        fan_in, fan_out = cfg.layer_sizes[i], cfg.layer_sizes[i + 1]
        key, sub = jax.random.split(key)
        lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
        params["layers"].append(
            {
                "w": jax.random.uniform(
                    sub, (fan_in, fan_out), dtype, minval=-lim, maxval=lim
                ),
                "gamma": jnp.ones((fan_out,), dtype),
                "beta": jnp.zeros((fan_out,), dtype),
                "mean": jnp.zeros((fan_out,), dtype),
                "var": jnp.ones((fan_out,), dtype),
            }
        )
    return params


def _bn_train(y, layer, eps, momentum):
    mu = jnp.mean(y, axis=0)
    var = jnp.var(y, axis=0)
    y_hat = (y - mu) / jnp.sqrt(var + eps)
    out = layer["gamma"] * y_hat + layer["beta"]
    new_stats = {
        "mean": momentum * layer["mean"] + (1 - momentum) * mu,
        "var": momentum * layer["var"] + (1 - momentum) * var,
    }
    return out, new_stats


def _bn_eval(y, layer, eps):
    y_hat = (y - layer["mean"]) / jnp.sqrt(layer["var"] + eps)
    return layer["gamma"] * y_hat + layer["beta"]


def forward(
    params: Params,
    x_pm1: jax.Array,
    cfg: MLPConfig,
    *,
    train: bool = False,
):
    """Forward pass on +-1 inputs.

    Returns (logits, new_params): full-precision post-BN logits of the last
    layer (training/eval criterion only) and BN-stat-updated params when
    `train=True` (otherwise params returned unchanged).
    """
    h = x_pm1
    new_layers = []
    for i, layer in enumerate(params["layers"]):
        wb = sign_ste(layer["w"])
        y = h @ wb  # binary dot product (+-1 domain); POPCOUNT equivalent
        if train:
            y, stats = _bn_train(y, layer, cfg.bn_eps, cfg.bn_momentum)
            new_layers.append({**layer, **stats})
        else:
            y = _bn_eval(y, layer, cfg.bn_eps)
            new_layers.append(layer)
        if i < cfg.n_layers - 1:
            h = sign_ste(y)  # binary activation between layers
    return y, {**params, "layers": new_layers}


def loss_fn(params: Params, x_pm1, labels, cfg: MLPConfig):
    """Cross-entropy on the (training-only) full-precision logits."""
    logits, new_params = forward(params, x_pm1, cfg, train=True)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return nll, new_params


@dataclasses.dataclass(frozen=True)
class FoldedLayer:
    """Deployment form of one binary layer: Eq. (3) data.

    weights_pm1 : [out, in] +-1 rows (note: transposed to row-per-neuron)
    c           : [out] integer BN constants C_j
    """

    weights_pm1: np.ndarray
    c: np.ndarray

    @property
    def n_out(self) -> int:
        """Output neurons (CAM rows)."""
        return self.weights_pm1.shape[0]

    @property
    def n_in(self) -> int:
        """Input bits per row (the XNOR-popcount dot width)."""
        return self.weights_pm1.shape[1]


def parity_adjust_c(c: np.ndarray, n_in: int, bias_cells: int) -> np.ndarray:
    """Clip C_j to the bias-cell budget with dead-zone-free parity.

    y = <W_j, x> has the parity of n_in, so sign(y + C) has a dead zone
    (y + C == 0) unless C has the opposite parity.  Nudging C up by one
    is exactly decision-preserving on the even grid
    (y + C >= 0  <=>  y + C + 1 > 0); clipping can land back on the
    dead-zone parity only at the bounds, where we step one inward.
    Shared by `fold` and the benchmark/test folded-net constructors.
    """
    c = np.asarray(c, np.int64)
    c = np.where((c + n_in) % 2 == 0, c + 1, c)
    c = np.clip(c, -bias_cells, bias_cells)
    return np.where((c + n_in) % 2 == 0, c - np.sign(c).astype(c.dtype), c)


def fold(params: Params, cfg: MLPConfig) -> list[FoldedLayer]:
    """Collapse trained BN into integer C_j per neuron (Eq. 3). Numpy-side."""
    folded = []
    for layer in params["layers"]:
        w = np.asarray(jnp.sign(layer["w"]))
        w = np.where(w == 0, 1.0, w).T  # [out, in], sign(0) -> +1
        gamma = np.asarray(layer["gamma"], np.float64)
        beta = np.asarray(layer["beta"], np.float64)
        mu = np.asarray(layer["mean"], np.float64)
        sigma = np.sqrt(np.asarray(layer["var"], np.float64) + cfg.bn_eps)
        # BN(y) >= 0 <=> sgn(g)*y >= sgn(g)*(mu - beta*sigma/gamma)
        flip = gamma < 0
        w = np.where(flip[:, None], -w, w)
        thresh = mu - beta * sigma / np.where(gamma == 0, 1e-12, gamma)
        thresh = np.where(flip, -thresh, thresh)
        c = np.round(-thresh).astype(np.int64)
        # C_j realized with cfg.bias_cells CAM cells: clip and match parity
        # of the dot product so sign(y + C) has no dead zone
        c = parity_adjust_c(c, w.shape[1], cfg.bias_cells)
        folded.append(FoldedLayer(weights_pm1=w.astype(np.int8), c=c))
    return folded


def folded_forward_exact(
    folded: Sequence[FoldedLayer], x_pm1: jax.Array
) -> jax.Array:
    """Eq. (3) reference semantics of the deployed net (digital oracle).

    Runs every layer as sign(W x + C); returns the *integer pre-sign* of
    the final layer (W_L h + C_L) — the quantity whose argmax Algorithm 1
    recovers through binary votes. Used as the oracle in tests/benchmarks.
    """
    h = x_pm1.astype(jnp.float32)
    for i, layer in enumerate(folded):
        w = jnp.asarray(layer.weights_pm1, jnp.float32)
        c = jnp.asarray(layer.c, jnp.float32)
        y = h @ w.T + c
        if i < len(folded) - 1:
            h = jnp.where(y >= 0, 1.0, -1.0)
    return y


def train_mlp(
    key: jax.Array,
    cfg: MLPConfig,
    train_x: np.ndarray,
    train_y: np.ndarray,
    *,
    epochs: int = 10,
    batch: int = 128,
    lr: float = 1e-3,
    weight_decay: float = 0.0,
    verbose: bool = False,
) -> Params:
    """Adam on latent weights with [-1, 1] latent clipping (BinaryConnect)."""
    params = init_params(key, cfg)
    flat, treedef = jax.tree_util.tree_flatten(params)
    m = [jnp.zeros_like(x) for x in flat]
    v = [jnp.zeros_like(x) for x in flat]

    grad_fn = jax.jit(
        lambda p, x, y: jax.grad(loss_fn, has_aux=True)(p, x, y, cfg)
    )

    @jax.jit
    def adam_update(flat, m, v, gflat, t):
        b1, b2, eps = 0.9, 0.999, 1e-8
        out_f, out_m, out_v = [], [], []
        for x, mi, vi, g in zip(flat, m, v, gflat):
            mi = b1 * mi + (1 - b1) * g
            vi = b2 * vi + (1 - b2) * g * g
            mh = mi / (1 - b1**t)
            vh = vi / (1 - b2**t)
            x = x - lr * mh / (jnp.sqrt(vh) + eps)
            out_f.append(x)
            out_m.append(mi)
            out_v.append(vi)
        return out_f, out_m, out_v

    n = train_x.shape[0]
    steps_per_epoch = max(n // batch, 1)
    t = 0
    rng = np.random.default_rng(0)
    for epoch in range(epochs):
        perm = rng.permutation(n)
        for s in range(steps_per_epoch):
            idx = perm[s * batch : (s + 1) * batch]
            xb = jnp.asarray(train_x[idx])
            yb = jnp.asarray(train_y[idx])
            grads, new_params = grad_fn(params, xb, yb)
            # BN running stats come back through the aux output
            params = new_params
            gflat = jax.tree_util.tree_leaves(grads)
            flat = jax.tree_util.tree_leaves(params)
            t += 1
            flat, m, v = adam_update(flat, m, v, gflat, t)
            # clip latent weights to [-1, 1] (BinaryConnect); BN params free
            params = jax.tree_util.tree_unflatten(treedef, flat)
            for layer in params["layers"]:
                layer["w"] = jnp.clip(layer["w"], -1.0, 1.0)
        if verbose:
            logits, _ = forward(params, jnp.asarray(train_x[:2048]), cfg)
            acc = float(
                (jnp.argmax(logits, -1) == jnp.asarray(train_y[:2048])).mean()
            )
            print(f"  epoch {epoch + 1}/{epochs}: train-acc(sample)={acc:.4f}")
    return params


def eval_accuracy(params: Params, cfg: MLPConfig, x, y, topk=(1,)) -> dict:
    """Top-k accuracy of the full-precision-logit software path."""
    logits, _ = forward(params, jnp.asarray(x), cfg)
    order = jnp.argsort(-logits, axis=-1)
    out = {}
    yj = jnp.asarray(y)[:, None]
    for k in topk:
        out[f"top{k}"] = float((order[:, :k] == yj).any(-1).mean())
    return out

"""Behavioural model of the PiC-BNN analog matchline (ML) circuitry.

The silicon senses the Hamming distance between a query (asserted on the
searchlines) and a stored row through the *discharge rate* of the matchline:
every mismatching bitcell opens one pull-down path, so more mismatches =>
faster discharge.  The MLSA compares ``V_ML`` at a sampling time ``t_s``
against a reference ``V_ref``; three user-configurable voltages set the
effective Hamming-distance (HD) tolerance threshold (paper Sec. III/IV,
Table I):

  * ``V_ref``  — MLSA reference:  lower V_ref -> larger HD tolerance.
  * ``V_eval`` — gate voltage of the per-cell ``M_eval`` footer transistor:
                 lower V_eval -> slower discharge -> larger HD tolerance.
  * ``V_st``   — controls MLSA sampling time: earlier sampling -> larger
                 HD tolerance.

Behavioural equation (RC discharge with ``m`` open pull-down paths)::

    V_ML(t; m) = VDD * exp(-m * g(V_eval) * t(V_st) / C_ML)

    match  <=>  V_ML(t_s) > V_ref
           <=>  m < HD_threshold(V_ref, V_eval, V_st)

with ``g(v)`` the (saturated) conductance of M_eval, modelled as
alpha-power-law ``g(v) = k * max(v - V_TH, 0)**alpha``, and the sampling
time an affine function of V_st.  Solving for the match condition::

    m* = ln(VDD / V_ref) * C / (g(V_eval) * t_s(V_st))

This module provides:
  * :class:`AnalogParams` — the physical constants (VDD, V_TH, alpha, ...)
  * :func:`hd_threshold` — the (V_ref, V_eval, V_st) -> HD threshold map
  * :func:`calibrate_table1` — least-squares fit of the free constants to
    the ten silicon operating points of Table I
  * :class:`NoiseModel` — PVT variation: Gaussian noise on V_ref, V_eval
    sampling jitter and per-cell discharge mismatch.  This is the physical
    source of randomness that the paper's law-of-large-numbers argument
    (Sec. IV) relies upon: near-threshold rows flip stochastically between
    passes, so the per-class vote count across the 33-threshold sweep is a
    Bernoulli average that concentrates on the true HD rank.
  * energy/latency constants reproducing Table II (used by core/mapping.py)

Everything here is differentiable-free NumPy/JAX arithmetic; the model is
behavioural, not SPICE — its purpose is to make the *accuracy* claims of the
paper testable under silicon-like (noisy, analog) conditions, and to ground
the throughput/energy benchmark in the measured numbers.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Silicon operating points: Table I of the paper.
#   (V_ref [mV], V_eval [mV], V_st [mV]) -> HD tolerance threshold
# --------------------------------------------------------------------------
TABLE1 = np.array(
    [
        # V_ref, V_eval, V_st, HD
        [1200.0, 1200.0, 1200.0, 0.0],
        [750.0, 950.0, 1200.0, 4.0],
        [775.0, 600.0, 1200.0, 8.0],
        [1175.0, 350.0, 1150.0, 12.0],
        [950.0, 525.0, 1100.0, 16.0],
        [1025.0, 475.0, 1000.0, 20.0],
        [950.0, 500.0, 1025.0, 24.0],
        [775.0, 600.0, 1100.0, 28.0],
        [1175.0, 400.0, 1150.0, 32.0],
        [1000.0, 475.0, 725.0, 36.0],
    ]
)

# Table II silicon measurements (used for the performance/energy model).
TECHNOLOGY_NM = 65
VDD_V = 1.2
SOC_AREA_MM2 = 2.38
PICBNN_AREA_MM2 = 0.87
PICBNN_CAPACITY_KBIT = 128
PICBNN_POWER_MW = 0.8
SOC_POWER_MW = 0.3  # PiC-BNN + RISC-V control processor ("overall")
PICBNN_TOPS = 184.0
CLOCK_HZ = 25e6
MNIST_INFERENCES_PER_S = 560e3
INFERENCES_PER_S_PER_W = 703e6
BITCELL_AREA_UM2 = 3.24
BANK_AREA_MM2 = 0.21
N_BANKS = 4

# Logical bank configurations (paper Sec. III): rows x row-width.
BANK_CONFIGS = ((512, 256), (1024, 128), (2048, 64))


@dataclasses.dataclass(frozen=True)
class AnalogParams:
    """Free constants of the behavioural matchline model.

    The defaults are the result of :func:`calibrate_table1` (least squares
    over the ten Table I silicon points); re-run the calibration to refresh.
    """

    vdd: float = 1.2  # supply [V]
    v_th: float = 0.30  # M_eval threshold voltage [V] (65nm regular-VT)
    alpha: float = 1.3  # alpha-power-law exponent (short channel)
    # Discharge constant: ln(VDD/V_ref) * c_over_g / (g_rel * t_rel) = m*
    c_over_g: float = 250.0  # lumped C_ML / k  [fitted, dimensionless scale]
    # Sampling time model: t_s = t0 + t1 * (VDD - V_st); lower V_st samples
    # later (the paper: *advancing* sampling raises HD tolerance).
    t0: float = 0.35
    t1: float = 1.0

    def g_rel(self, v_eval):
        """Relative conductance of M_eval (alpha-power law, saturated)."""
        v_ov = jnp.maximum(v_eval - self.v_th, 1e-6)
        return v_ov**self.alpha

    def t_sample(self, v_st):
        """Relative MLSA sampling time as a function of V_st.

        Table I shows *lower* V_st used for the largest tolerances together
        with re-tuned V_ref/V_eval; we model t_s as affine in (VDD - V_st):
        lowering V_st delays the sample, letting more charge bleed away for
        the same mismatch count -> higher apparent HD at the comparison.
        """
        return self.t0 + self.t1 * jnp.maximum(self.vdd - v_st, 0.0)


def hd_threshold(params: AnalogParams, v_ref, v_eval, v_st):
    """Continuous HD tolerance threshold m* for a knob setting (volts).

    A row *matches* iff its Hamming distance m satisfies ``m <= m*``.
    ``m* = ln(VDD / V_ref) * (C/k) / (g_rel(V_eval) * t_s(V_st))``
    with the convention that V_ref == VDD gives m* = 0 (exact match).
    """
    v_ref = jnp.asarray(v_ref, jnp.float32)
    # ln(VDD/V_ref): 0 at exact-match setting, grows as V_ref drops.
    lnr = jnp.log(jnp.maximum(params.vdd / jnp.minimum(v_ref, params.vdd), 1.0))
    return params.c_over_g * lnr / (params.g_rel(v_eval) * params.t_sample(v_st))


def table1_residuals(params: AnalogParams) -> np.ndarray:
    """Model-vs-silicon HD threshold residuals over the Table I points."""
    v = TABLE1
    pred = np.asarray(
        hd_threshold(params, v[:, 0] / 1e3, v[:, 1] / 1e3, v[:, 2] / 1e3)
    )
    return pred - v[:, 3]


def calibrate_table1(iters: int = 200, seed: int = 0) -> tuple[AnalogParams, float]:
    """Least-squares fit of the free model constants against Table I.

    Multi-start trust-region least squares over (c_over_g, alpha, v_th,
    t0, t1).  The silicon HD-vs-knob surface is non-monotone in V_eval
    (compare Table I rows 4 and 9: +50 mV on V_eval jumps the threshold
    from 12 to 32 at fixed V_ref/V_st), so a smooth 5-parameter physical
    model cannot interpolate every point — the residual RMSE of ~6-7 HD
    units is a property of the data, not the optimizer.  Per-chip accuracy
    is recovered by :class:`CalibratedModel`, which adds an RBF residual
    anchored at the measured operating points (exactly what silicon
    bring-up does with per-die calibration LUTs).

    Returns (fitted params, RMSE in HD units).
    """
    from scipy.optimize import least_squares  # deferred: host-side only

    v = TABLE1
    vr, ve, vs, hd = v[:, 0] / 1e3, v[:, 1] / 1e3, v[:, 2] / 1e3, v[:, 3]

    def predict(theta):
        c, a, vt, t0, t1 = theta
        g = np.maximum(ve - vt, 1e-4) ** a
        ts = np.maximum(t0 + t1 * np.maximum(1.2 - vs, 0.0), 1e-3)
        lnr = np.log(np.maximum(1.2 / np.minimum(vr, 1.2), 1.0))
        return c * lnr / (g * ts)

    def resid(theta):
        return predict(theta) - hd

    rng = np.random.default_rng(seed)
    best = None
    lo = [1.0, 0.3, 0.0, 0.01, 0.0]
    hi = [5000.0, 2.5, 0.34, 5.0, 10.0]
    for _ in range(iters):
        x0 = np.array([rng.uniform(l, h) for l, h in zip(lo, hi)])
        try:
            r = least_squares(resid, x0, bounds=(lo, hi))
        except Exception:
            continue
        if best is None or r.cost < best.cost:
            best = r
    assert best is not None
    c, a, vt, t0, t1 = (float(x) for x in best.x)
    fitted = AnalogParams(c_over_g=c, alpha=a, v_th=vt, t0=t0, t1=t1)
    rmse = float(np.sqrt(np.mean(table1_residuals(fitted) ** 2)))
    return fitted, rmse


@dataclasses.dataclass(frozen=True)
class CalibratedModel:
    """Physical model + per-chip RBF residual anchored at Table I points.

    ``hd_threshold(knobs)`` = physical(knobs) + rbf_residual(knobs); exact
    (by construction) at the ten measured silicon operating points, smooth
    in between.  This mirrors silicon practice: the analytic model gives
    the trend, per-die calibration closes the loop.
    """

    params: AnalogParams
    _rbf: object  # scipy RBFInterpolator over (V_ref, V_eval, V_st) [V]

    @classmethod
    def fit(cls, params: Optional[AnalogParams] = None) -> "CalibratedModel":
        from scipy.interpolate import RBFInterpolator

        if params is None:
            params, _ = calibrate_table1()
        pts = TABLE1[:, :3] / 1e3
        res = -table1_residuals(params)  # correction = measured - model
        rbf = RBFInterpolator(pts, res, kernel="thin_plate_spline")
        return cls(params=params, _rbf=rbf)

    def hd_threshold(self, v_ref, v_eval, v_st) -> np.ndarray:
        knobs = np.stack(
            np.broadcast_arrays(
                np.asarray(v_ref, float),
                np.asarray(v_eval, float),
                np.asarray(v_st, float),
            ),
            axis=-1,
        ).reshape(-1, 3)
        base = np.asarray(
            hd_threshold(self.params, knobs[:, 0], knobs[:, 1], knobs[:, 2])
        )
        corrected = base + self._rbf(knobs)
        return np.maximum(corrected, 0.0).reshape(np.shape(v_ref))

    def residuals_table1(self) -> np.ndarray:
        v = TABLE1
        pred = self.hd_threshold(v[:, 0] / 1e3, v[:, 1] / 1e3, v[:, 2] / 1e3)
        return pred - v[:, 3]


# --------------------------------------------------------------------------
# PVT noise model (Sec. IV: the randomness behind the LLN argument)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NoiseModel:
    """Gaussian PVT variation applied to a CAM search.

    sigma_hd        — per-row equivalent input-referred noise, in HD units
                      (lumps MLSA offset + discharge-path mismatch).
    sigma_vref      — V_ref drift [V] converted through d(m*)/d(V_ref).
    sigma_tjitter   — relative sampling-time jitter (fraction of t_s).
    temp_drift_hd   — deterministic HD-threshold offset (temperature drift;
                      systematic, i.e. shared by all rows in one pass —
                      exactly the failure mode the paper ascribes to
                      TDC-based competitors).
    """

    sigma_hd: float = 1.0
    sigma_vref: float = 0.01
    sigma_tjitter: float = 0.02
    temp_drift_hd: float = 0.0

    @property
    def is_active(self) -> bool:
        """True when ANY non-ideality (random sigma or drift) is nonzero."""
        return bool(
            self.sigma_hd
            or self.sigma_vref
            or self.sigma_tjitter
            or self.temp_drift_hd
        )

    def effective_threshold(
        self, key: jax.Array, params: AnalogParams, v_ref, v_eval, v_st, shape=()
    ):
        """Sample a per-row effective HD threshold under PVT noise.

        Returns a float array of `shape`: the HD threshold actually applied
        by the analog comparison for each row in this pass.  The sampling
        itself lives in `core/physics.py` (the unified noise module); this
        method is a thin delegate kept for API stability.
        """
        from repro.core import physics  # deferred: avoid circular import

        return physics.sample_effective_threshold(
            key, params, self, v_ref, v_eval, v_st, shape
        )


NOISELESS = NoiseModel(sigma_hd=0.0, sigma_vref=0.0, sigma_tjitter=0.0)

# Silicon-like default: ~1 HD unit of row noise, 10 mV V_ref sigma, 2% jitter
SILICON = NoiseModel()


# --------------------------------------------------------------------------
# Energy / latency constants for the mapping model (Table II grounding)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """Per-operation energy/latency derived from Table II silicon figures.

    One CAM search over a bank of R rows x W bits performs R*W binary MACs
    (XNOR+accumulate) in a single cycle.  At 25 MHz and 0.8 mW:
      energy/cycle = 0.8 mW / 25 MHz = 32 pJ
    Peak binary throughput with all four banks in 2048x64 config:
      4 banks * 2048 rows * 64 bits * 2 ops * 25 MHz = 26.2 TOPS ... the
    paper's 184 TOPS/W is an *efficiency* figure: 26.2 TOPS / (0.8+0.3)mW
    region; we expose both raw numbers and let benchmarks derive Table II.
    """

    clock_hz: float = CLOCK_HZ
    power_w: float = PICBNN_POWER_MW * 1e-3
    soc_power_w: float = (PICBNN_POWER_MW + SOC_POWER_MW) * 1e-3
    tuning_cycles: int = 2500  # voltage re-tune latency (amortized, Sec. V-B)

    @property
    def energy_per_cycle_j(self) -> float:
        return self.power_w / self.clock_hz

    def search_energy_j(self, rows: int, width: int) -> float:
        """Energy of one search cycle, scaled by active array fraction."""
        full = 4 * 2048 * 64  # all banks active, largest config
        frac = (rows * width) / full
        return self.energy_per_cycle_j * max(min(frac, 1.0), 0.01)

    def ops_per_search(self, rows: int, width: int) -> int:
        return 2 * rows * width  # XNOR + accumulate per bitcell


@functools.lru_cache(maxsize=1)
def default_params() -> AnalogParams:
    """Calibrated-by-default analog constants (cached)."""
    params, _rmse = calibrate_table1(iters=60)
    return params


@functools.lru_cache(maxsize=1)
def default_calibrated() -> CalibratedModel:
    return CalibratedModel.fit(default_params())


def knob_schedule(
    n_thresholds: int,
    max_hd: int,
    params: Optional[AnalogParams] = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Produce a (V_ref, V_eval, V_st) schedule sweeping HD tolerance.

    The silicon sweeps HD thresholds {0, 2, 4, ..., 64} (Algorithm 1) by
    re-tuning the three knobs per pass.  We anchor the schedule on the ten
    measured Table I operating points and solve the remaining settings by
    inverting the behavioural model around them: hold V_eval/V_st at the
    nearest anchor's values and solve V_ref for the target threshold
    (V_ref is the fastest knob to re-tune in silicon); clip to the MLSA
    feasible range and fall back to V_eval adjustment where V_ref alone
    cannot reach.

    Returns (knobs [n,3] in volts, achieved HD thresholds [n] under the
    calibrated model).
    """
    params = params or default_params()
    cal = default_calibrated()
    targets = np.linspace(0.0, max_hd, n_thresholds)
    # nearest Table I anchor per target (by HD threshold)
    anchor_idx = np.abs(TABLE1[:, 3][None, :] - targets[:, None]).argmin(1)
    v_eval = TABLE1[anchor_idx, 1] / 1e3
    v_st = TABLE1[anchor_idx, 2] / 1e3
    # Invert the calibrated model per target with a V_ref grid search
    # (V_ref is the fastest knob to re-tune; the RBF correction makes the
    # surface only piecewise-monotone, so a dense grid beats bisection).
    grid = np.linspace(0.30, params.vdd, 512)
    v_ref = np.empty(n_thresholds)
    for i, tgt in enumerate(targets):
        pred = cal.hd_threshold(
            grid, np.full_like(grid, v_eval[i]), np.full_like(grid, v_st[i])
        )
        v_ref[i] = grid[np.abs(pred - tgt).argmin()]
    knobs = np.stack([v_ref, v_eval, v_st], axis=-1).astype(np.float32)
    achieved = cal.hd_threshold(knobs[:, 0], knobs[:, 1], knobs[:, 2])
    return knobs, np.asarray(achieved)

"""Binarization primitives: sign with straight-through estimator, bit
packing/unpacking, and Hamming-distance utilities.

Conventions (match the paper, Sec. II-B):
  logical bit b in {0, 1}  <->  value v = 2b - 1 in {-1, +1}
  weight/activation "match" (XNOR == 1)  <->  product v_w * v_x = +1

Packed representation: bits are packed little-endian into uint32 words along
the last axis; `valid_len` tracks the logical (unpadded) bit length.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32


@jax.custom_vjp
def sign_ste(x):
    """sign(x) in {-1, +1} with the clipped straight-through estimator.

    Forward: sign(x) (0 maps to +1, matching the paper's logic-'1' coding).
    Backward: dL/dx = dL/dy * 1[|x| <= 1]  (Hinton STE / BinaryConnect).
    """
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _sign_ste_fwd(x):
    return sign_ste(x), x


def _sign_ste_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


sign_ste.defvjp(_sign_ste_fwd, _sign_ste_bwd)


def to_bits(values):
    """±1 values (any float/int dtype) -> {0,1} uint8 bits."""
    return (values > 0).astype(jnp.uint8)


def from_bits(bits, dtype=jnp.float32):
    """{0,1} bits -> ±1 values."""
    return (2 * bits.astype(jnp.int8) - 1).astype(dtype)


def packed_width(n_bits: int) -> int:
    return -(-n_bits // WORD)


# Power-of-two vectors for the dot-product pack fast path. The word is
# packed as two 16-bit halves so every partial sum stays int32-exact
# (a single 32-bit dot would need bit 31 = 2^31, which overflows int32).
_POW2_HALF = np.asarray(1 << np.arange(WORD // 2), np.int32)


def pack_bits(bits):
    """Pack {0,1} bits along the last axis into uint32 words (little-endian).

    Pads with 0 to a multiple of 32. Padding bits are 0 on both operands of a
    Hamming distance, so XOR over padding contributes nothing.

    Fast path: each 16-bit half-word is a single dot against the
    power-of-two vector (int32-exact), and the two halves combine with one
    shift-or — replacing the shift-broadcast-sum that materialized a
    [..., kw, 32] uint32 temporary and reduced it lane by lane.
    """
    *lead, k = bits.shape
    kw = packed_width(k)
    pad = kw * WORD - k
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * len(lead) + [(0, pad)])
    halves = bits.reshape(*lead, kw * 2, WORD // 2).astype(jnp.int32)
    pow2 = jnp.asarray(_POW2_HALF)
    words16 = jax.lax.dot_general(
        halves, pow2,
        (((halves.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.uint32)
    words16 = words16.reshape(*lead, kw, 2)
    return words16[..., 0] | (words16[..., 1] << jnp.uint32(16))


def pack_bits_reference(bits):
    """The original shift-broadcast-sum pack (kept as oracle/baseline)."""
    *lead, k = bits.shape
    kw = packed_width(k)
    pad = kw * WORD - k
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * len(lead) + [(0, pad)])
    bits = bits.reshape(*lead, kw, WORD).astype(jnp.uint32)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def unpack_bits(words, n_bits: int):
    """uint32 words -> {0,1} uint8 bits, truncated to n_bits."""
    *lead, kw = words.shape
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*lead, kw * WORD)[..., :n_bits].astype(jnp.uint8)


def pack_pm1(values):
    """±1 values -> packed uint32 words."""
    return pack_bits(to_bits(values))


def hamming_packed(a, b):
    """Hamming distance between packed bit vectors (broadcasts leading dims)."""
    return jnp.bitwise_count(jnp.bitwise_xor(a, b)).astype(jnp.int32).sum(-1)


def hamming_pm1(a, b):
    """Hamming distance between ±1 vectors: #positions where they differ."""
    return jnp.sum(a * b < 0, axis=-1).astype(jnp.int32)


def dot_from_hd(hd, n_bits):
    """XNOR-popcount 'dot product' from Hamming distance.

    matches - mismatches = (n - hd) - hd = n - 2*hd  ==  <v_a, v_b> in ±1.
    """
    return n_bits - 2 * hd


def hd_from_dot(dot, n_bits):
    return (n_bits - dot) // 2


@functools.partial(jax.jit, static_argnames=("n_bits",))
def binary_matvec_packed(w_packed, x_packed, n_bits: int):
    """y_j = sum_i XNOR(+/-)(W_ji, x_i) over packed rows.

    w_packed: [N, Kw] uint32;  x_packed: [..., Kw] uint32.
    Returns [..., N] int32 dot products in the ±1 domain.

    Routed through the tiled Pallas popcount GEMM (kernels.binary_gemm) —
    the broadcast XOR it replaces materialized an O(B*N*Kw) uint32
    temporary in HBM; the kernel keeps each (bm, bn) tile's working set
    in VMEM.
    """
    from repro.kernels import ops  # deferred: core stays import-light

    *lead, kw = x_packed.shape
    hd = ops.binary_gemm_hd(
        x_packed.reshape(-1, kw), w_packed, bm=128, bn=128
    )
    return dot_from_hd(hd, n_bits).reshape(*lead, w_packed.shape[0])


def random_pm1(key, shape, dtype=jnp.float32):
    return from_bits(jax.random.bernoulli(key, 0.5, shape), dtype)


def np_pack_bits(bits: np.ndarray) -> np.ndarray:
    """NumPy twin of pack_bits (for host-side dataset/CAM construction)."""
    *lead, k = bits.shape
    kw = packed_width(k)
    pad = kw * WORD - k
    if pad:
        bits = np.pad(bits, [(0, 0)] * len(lead) + [(0, pad)])
    bits = bits.reshape(*lead, kw, WORD).astype(np.uint64)
    return (bits << np.arange(WORD, dtype=np.uint64)).sum(-1).astype(np.uint32)

"""Binarization primitives: sign with straight-through estimator, bit
packing/unpacking, and Hamming-distance utilities.

Conventions (match the paper, Sec. II-B):
  logical bit b in {0, 1}  <->  value v = 2b - 1 in {-1, +1}
  weight/activation "match" (XNOR == 1)  <->  product v_w * v_x = +1

Packed representation: bits are packed little-endian into uint32 words along
the last axis; `valid_len` tracks the logical (unpadded) bit length.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32


@jax.custom_vjp
def sign_ste(x):
    """sign(x) in {-1, +1} with the clipped straight-through estimator.

    Forward: sign(x) (0 maps to +1, matching the paper's logic-'1' coding).
    Backward: dL/dx = dL/dy * 1[|x| <= 1]  (Hinton STE / BinaryConnect).
    """
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _sign_ste_fwd(x):
    return sign_ste(x), x


def _sign_ste_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


sign_ste.defvjp(_sign_ste_fwd, _sign_ste_bwd)


def to_bits(values):
    """±1 values (any float/int dtype) -> {0,1} uint8 bits."""
    return (values > 0).astype(jnp.uint8)


def from_bits(bits, dtype=jnp.float32):
    """{0,1} bits -> ±1 values."""
    return (2 * bits.astype(jnp.int8) - 1).astype(dtype)


def packed_width(n_bits: int) -> int:
    """uint32 words needed for n_bits packed bits (ceil division)."""
    return -(-n_bits // WORD)


# Power-of-two vectors for the dot-product pack fast path. The word is
# packed as two 16-bit halves so every partial sum stays int32-exact
# (a single 32-bit dot would need bit 31 = 2^31, which overflows int32).
_POW2_HALF = np.asarray(1 << np.arange(WORD // 2), np.int32)


def pack_bits(bits):
    """Pack {0,1} bits along the last axis into uint32 words (little-endian).

    Pads with 0 to a multiple of 32. Padding bits are 0 on both operands of a
    Hamming distance, so XOR over padding contributes nothing.

    Fast path: each 16-bit half-word is a single dot against the
    power-of-two vector (int32-exact), and the two halves combine with one
    shift-or — replacing the shift-broadcast-sum that materialized a
    [..., kw, 32] uint32 temporary and reduced it lane by lane.
    """
    *lead, k = bits.shape
    kw = packed_width(k)
    pad = kw * WORD - k
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * len(lead) + [(0, pad)])
    halves = bits.reshape(*lead, kw * 2, WORD // 2).astype(jnp.int32)
    pow2 = jnp.asarray(_POW2_HALF)
    words16 = jax.lax.dot_general(
        halves, pow2,
        (((halves.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.uint32)
    words16 = words16.reshape(*lead, kw, 2)
    return words16[..., 0] | (words16[..., 1] << jnp.uint32(16))


def pack_bits_reference(bits):
    """The original shift-broadcast-sum pack (kept as oracle/baseline)."""
    *lead, k = bits.shape
    kw = packed_width(k)
    pad = kw * WORD - k
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * len(lead) + [(0, pad)])
    bits = bits.reshape(*lead, kw, WORD).astype(jnp.uint32)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def unpack_bits(words, n_bits: int):
    """uint32 words -> {0,1} uint8 bits, truncated to n_bits."""
    *lead, kw = words.shape
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*lead, kw * WORD)[..., :n_bits].astype(jnp.uint8)


def pack_pm1(values):
    """±1 values -> packed uint32 words."""
    return pack_bits(to_bits(values))


def hamming_packed(a, b):
    """Hamming distance between packed bit vectors (broadcasts leading dims)."""
    return jnp.bitwise_count(jnp.bitwise_xor(a, b)).astype(jnp.int32).sum(-1)


def hamming_pm1(a, b):
    """Hamming distance between ±1 vectors: #positions where they differ."""
    return jnp.sum(a * b < 0, axis=-1).astype(jnp.int32)


def dot_from_hd(hd, n_bits):
    """XNOR-popcount 'dot product' from Hamming distance.

    matches - mismatches = (n - hd) - hd = n - 2*hd  ==  <v_a, v_b> in ±1.
    """
    return n_bits - 2 * hd


def hd_from_dot(dot, n_bits):
    """Inverse of `dot_from_hd`: Hamming distance from the ±1 dot."""
    return (n_bits - dot) // 2


@functools.partial(jax.jit, static_argnames=("n_bits",))
def binary_matvec_packed(w_packed, x_packed, n_bits: int):
    """y_j = sum_i XNOR(+/-)(W_ji, x_i) over packed rows.

    w_packed: [N, Kw] uint32;  x_packed: [..., Kw] uint32.
    Returns [..., N] int32 dot products in the ±1 domain.

    Routed through the tiled Pallas popcount GEMM (kernels.binary_gemm) —
    the broadcast XOR it replaces materialized an O(B*N*Kw) uint32
    temporary in HBM; the kernel keeps each (bm, bn) tile's working set
    in VMEM.
    """
    from repro.kernels import ops  # deferred: core stays import-light

    *lead, kw = x_packed.shape
    hd = ops.binary_gemm_hd(
        x_packed.reshape(-1, kw), w_packed, bm=128, bn=128
    )
    return dot_from_hd(hd, n_bits).reshape(*lead, w_packed.shape[0])


# ---------------------------------------------------------------------------
# Binary input layer: [0, 1] intensity -> multi-bit binary codes
# ---------------------------------------------------------------------------
# The paper's end-to-end claim binarizes the INPUT layer too (typical BNNs
# keep it full precision).  A single sign threshold throws away all
# magnitude information; these encodings expand each [0, 1] intensity into
# `width` binary channels so the first (binary) conv layer sees a graded
# input while the whole network still computes only on bits.


def thermometer_bits(x01, width: int):
    """[0,1] intensities -> thermometer code, [..., width] {0,1} uint8.

    Bit t fires iff x >= (t+1)/(width+1): the code is monotone (all ones
    below the fill level, zeros above), so the XNOR-popcount dot of two
    codes is monotone in |x - y| — Hamming distance between codes equals
    the quantized intensity gap, which is exactly the semantics the
    Hamming-tolerant CAM search expects.  width=1 reduces to the plain
    x >= 0.5 sign binarization (`data.synthetic.binarize_images`); an
    all-zero image encodes to all-zero bits (logical -1).
    """
    if width < 1:
        raise ValueError(f"thermometer width must be >= 1, got {width}")
    x = jnp.asarray(x01)
    thr = (jnp.arange(width, dtype=jnp.float32) + 1.0) / (width + 1.0)
    return (x[..., None] >= thr).astype(jnp.uint8)


def thermometer_decode(bits):
    """Thermometer code -> intensity estimate in [0,1] (level midpoint).

    Inverse of `thermometer_bits` up to quantization: with fill level
    k = sum(bits) of width T, x is known to lie in [k/(T+1), (k+1)/(T+1))
    (clamped at the top); the midpoint (k + 0.5)/(T + 1) minimizes the
    worst-case round-trip error of 0.5/(T+1).
    """
    bits = jnp.asarray(bits)
    width = bits.shape[-1]
    k = bits.astype(jnp.int32).sum(-1).astype(jnp.float32)
    return (k + 0.5) / (width + 1.0)


def bitplane_bits(x01, width: int):
    """[0,1] intensities -> binary expansion, [..., width] {0,1} uint8.

    Quantizes to round(x * (2^width - 1)) and emits the bit planes
    LSB-first (bit t has weight 2^t).  Denser than thermometer (width
    bits give 2^width levels vs width+1) but NOT Hamming-faithful: an
    XNOR-popcount dot weighs the MSB plane the same as the LSB plane, so
    HD between codes is not monotone in |x - y| (DESIGN.md §10 records
    the tradeoff).  Round-trips exactly on the 2^width-level grid
    (`bitplane_decode`).
    """
    if width < 1:
        raise ValueError(f"bit-plane width must be >= 1, got {width}")
    levels = (1 << width) - 1
    q = jnp.round(jnp.asarray(x01, jnp.float32) * levels).astype(jnp.uint32)
    shifts = jnp.arange(width, dtype=jnp.uint32)
    return ((q[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.uint8)


def bitplane_decode(bits):
    """Bit planes (LSB-first) -> intensity in [0,1]; exact on the grid."""
    bits = jnp.asarray(bits)
    width = bits.shape[-1]
    weights = (1 << jnp.arange(width, dtype=jnp.int32)).astype(jnp.float32)
    levels = float((1 << width) - 1)
    return (bits.astype(jnp.float32) * weights).sum(-1) / levels


@dataclasses.dataclass(frozen=True)
class InputEncoding:
    """How raw [0,1] pixels become the binary input channels of a CNN.

    kind  : "thermometer" (Hamming-faithful, width+1 levels — the
            default), "bitplane" (2^width levels, not Hamming-faithful),
            or "sign" (width must be 1; plain x >= 0.5).
    width : binary channels emitted per pixel (= C_in of the first conv
            layer).

    `encode_bits` maps [..., H, W] (or any shape) intensities to
    [..., width] {0,1} bits; `encode_pm1` maps to the ±1 domain the
    float oracles consume.  Both are deterministic and jit-safe.
    """

    kind: str = "thermometer"
    width: int = 8

    def __post_init__(self):
        if self.kind not in ("thermometer", "bitplane", "sign"):
            raise ValueError(f"unknown input encoding kind {self.kind!r}")
        if self.kind == "sign" and self.width != 1:
            raise ValueError("sign encoding is width-1 by definition")
        if self.width < 1:
            raise ValueError(f"encoding width must be >= 1: {self.width}")

    def encode_bits(self, x01):
        """[0,1] intensities [...] -> {0,1} uint8 bits [..., width]."""
        if self.kind == "bitplane":
            return bitplane_bits(x01, self.width)
        if self.kind == "sign":
            return (jnp.asarray(x01)[..., None] >= 0.5).astype(jnp.uint8)
        return thermometer_bits(x01, self.width)

    def encode_pm1(self, x01, dtype=jnp.float32):
        """[0,1] intensities [...] -> ±1 values [..., width]."""
        return from_bits(self.encode_bits(x01), dtype)


def random_pm1(key, shape, dtype=jnp.float32):
    """Uniform random ±1 array (fair coin per element)."""
    return from_bits(jax.random.bernoulli(key, 0.5, shape), dtype)


def np_pack_bits(bits: np.ndarray) -> np.ndarray:
    """NumPy twin of pack_bits (for host-side dataset/CAM construction)."""
    *lead, k = bits.shape
    kw = packed_width(k)
    pad = kw * WORD - k
    if pad:
        bits = np.pad(bits, [(0, 0)] * len(lead) + [(0, pad)])
    bits = bits.reshape(*lead, kw, WORD).astype(np.uint64)
    return (bits << np.arange(WORD, dtype=np.uint64)).sum(-1).astype(np.uint32)

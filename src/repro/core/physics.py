"""Unified search physics: the ONLY producer of effective HD thresholds.

Every noisy CAM search in this repo — `cam.CAMArray.search`, the Algorithm-1
ensemble (`ensemble.votes_faithful` / `accuracy_sweep`), and the fused
pipeline (`pipeline.compile_pipeline(..., noise=)` and both kernel twins) —
obtains its *effective* per-pass Hamming-distance thresholds from this
module.  Before this existed, three call sites each applied a different
subset of :class:`~repro.core.device_model.NoiseModel` (the "dead noise
gates": sigma_vref / sigma_tjitter were tested but never applied); now the
sampling semantics live in one place and the consumers only compare.

Physical picture (DESIGN.md §8): the matchline comparison is
``V_ML(t_s; HD) > V_ref``.  Every PVT non-ideality is referred to the
*threshold side* of that comparison, in HD units:

  sigma_vref    — V_ref drift, converted through the analytic sensitivity
                  ``d(m*)/dV_ref`` of the behavioural model at the pass's
                  knob operating point (`vref_sensitivity`).  One MLSA
                  reference per search => the draw is PASS-GLOBAL (shared
                  by every row of that search).
  sigma_tjitter — sampling-strobe jitter; ``m* ~ 1/t_s`` so it acts
                  multiplicatively on the pass's *logical* tolerance
                  magnitude.  One strobe per search => pass-global.
  sigma_hd      — MLSA offset + per-cell discharge mismatch, lumped as
                  input-referred noise in HD units.  PER-ROW draw.
  temp_drift_hd — deterministic systematic offset shared by all rows.

Referring per-row matchline noise to the threshold is distribution-exact:
``match <=> HD <= T + eps  <=>  HD - eps <= T`` — the Bernoulli vote
probabilities (and hence every vote-count moment) are identical whether the
noise is modeled on the analog HD reading or on the threshold.  This is
what lets the fused TPU paths (HD computed ONCE, 33 compares in-register)
keep exact silicon-noise semantics: thresholds are sampled as ``[P, ...]``
float arrays outside the kernel and only the compare changes.

Per-pass knob provenance: a full Algorithm-1 sweep takes its operating
points from the Table-I-calibrated :func:`device_model.knob_schedule`
(cached); a bare threshold with no schedule (a standalone `cam.search`)
falls back to the nearest Table-I anchor.  In the NOISELESS limit every
sampler in this module returns the base thresholds bit-exactly — the fused
noisy paths then equal the PR-1 noiseless oracle bit-for-bit (tested).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_model import (
    TABLE1,
    AnalogParams,
    NoiseModel,
    NOISELESS,
    default_params,
    hd_threshold,
    knob_schedule,
)


# ---------------------------------------------------------------------------
# Knob-space sensitivities and provenance
# ---------------------------------------------------------------------------
def vref_sensitivity(params: AnalogParams, v_ref, v_eval, v_st):
    """Analytic ``d(m*)/dV_ref`` of the behavioural matchline model [HD/V].

    ``m* = (C/k) ln(VDD/V_ref) / (g(V_eval) t_s(V_st))`` gives
    ``d(m*)/dV_ref = -(C/k) / (V_ref g t_s)`` — finite (and negative) even
    at the exact-match point V_ref = VDD where m* itself is zero.
    """
    v_ref = jnp.asarray(v_ref, jnp.float32)
    denom = params.g_rel(v_eval) * params.t_sample(v_st)
    return -params.c_over_g / (jnp.maximum(v_ref, 1e-3) * denom)


def anchor_knobs(threshold):
    """Nearest Table-I operating point by HD tolerance (elementwise).

    The knob provenance used when a caller supplies a bare threshold with
    no schedule (e.g. `cam.CAMArray.search`).  Traceable jnp arithmetic:
    returns (v_ref, v_eval, v_st) arrays broadcast like `threshold` [V].
    """
    thr = jnp.asarray(threshold, jnp.float32)
    anchors_hd = jnp.asarray(TABLE1[:, 3], jnp.float32)
    idx = jnp.argmin(jnp.abs(thr[..., None] - anchors_hd), axis=-1)
    knobs = jnp.asarray(TABLE1[:, :3] / 1e3, jnp.float32)[idx]
    return knobs[..., 0], knobs[..., 1], knobs[..., 2]


@functools.lru_cache(maxsize=8)
def _schedule_cached(n_passes: int, sweep_max: int):
    """Table-I-calibrated knob schedule, cached per (P, sweep span)."""
    knobs, achieved = knob_schedule(n_passes, sweep_max)
    return np.asarray(knobs, np.float32), np.asarray(achieved, np.float32)


def achieved_sweep(n_passes: int, sweep_max: int) -> np.ndarray:
    """The knob schedule's *achieved* calibrated logical tolerances [P].

    What the analog knobs actually deliver (under the per-die calibrated
    model) when asked for the ideal sweep ``linspace(0, sweep_max, P)`` —
    used by `ensemble.build_head(calibrated=True)` to deploy thresholds
    the silicon can realize instead of ideal integers.
    """
    return _schedule_cached(int(n_passes), int(sweep_max))[1]


# ---------------------------------------------------------------------------
# The one sampling core
# ---------------------------------------------------------------------------
def _sample_deltas(key, noise: NoiseModel, m_logical, dm_dvref,
                   global_shape: tuple, n_rows: int):
    """Threshold perturbations: the ONE place sigmas become randomness.

    m_logical / dm_dvref : broadcastable to ``global_shape + (1,)`` (or
        ``+ (n_rows,)``) — the logical tolerance magnitude the
        multiplicative time-jitter acts on, and the V_ref sensitivity.
    global_shape : shape of the pass-global draws — V_ref drift and strobe
        jitter are shared by every row of one search (one MLSA reference,
        one strobe per cycle).
    n_rows : trailing per-row axis for the sigma_hd draw.

    Returns float32 deltas of shape ``global_shape + (n_rows,)``.
    """
    kv, kt, kr = jax.random.split(key, 3)
    dv = noise.sigma_vref * jax.random.normal(kv, global_shape + (1,))
    tj = 1.0 + noise.sigma_tjitter * jax.random.normal(kt, global_shape + (1,))
    row = noise.sigma_hd * jax.random.normal(kr, global_shape + (n_rows,))
    return (
        dm_dvref * dv
        + m_logical * (1.0 / jnp.maximum(tj, 0.5) - 1.0)
        + row
        + noise.temp_drift_hd
    )


def sample_effective_threshold(
    key: jax.Array,
    params: AnalogParams,
    noise: NoiseModel,
    v_ref,
    v_eval,
    v_st,
    shape=(),
):
    """Exact knob-space sampler: perturb the voltages, then convert to HD.

    The reference (non-linearized) form used when the caller holds actual
    knob voltages (`cam.CAMArray.search_knobs`); `_sample_deltas` is its
    linearization around an operating point.  Moved verbatim from
    ``NoiseModel.effective_threshold`` (which now delegates here).
    """
    k1, k2, k3 = jax.random.split(key, 3)
    v_ref_n = v_ref + noise.sigma_vref * jax.random.normal(k1, shape)
    base = hd_threshold(params, v_ref_n, v_eval, v_st)
    # time jitter scales m* multiplicatively: m* ~ 1/t_s
    tj = 1.0 + noise.sigma_tjitter * jax.random.normal(k2, shape)
    base = base / jnp.maximum(tj, 0.5)
    row = noise.sigma_hd * jax.random.normal(k3, shape)
    return base + row + noise.temp_drift_hd


def sample_search_thresholds(
    key: Optional[jax.Array],
    threshold,
    noise: NoiseModel,
    shape: tuple,
    params: Optional[AnalogParams] = None,
):
    """Effective thresholds for a single-pass CAM search (no schedule).

    threshold : scalar or array broadcastable to `shape` ([..., n_rows]).
    shape     : target shape; the last axis is the row axis (per-row
                sigma_hd draws), leading axes are independent search
                cycles (pass-global vref/strobe draws).

    ``key=None`` or a noiseless model returns the base thresholds
    broadcast — bit-exact noiseless limit.
    """
    t = jnp.broadcast_to(jnp.asarray(threshold, jnp.float32), shape)
    if key is None or not noise.is_active:
        return t
    if noise.sigma_vref or noise.sigma_tjitter:
        # knob provenance on the raw (usually scalar) threshold — it
        # broadcasts against the delta shapes, no need to materialize
        # per-element anchors over [..., n_rows]
        params = params or default_params()
        t_raw = jnp.asarray(threshold, jnp.float32)
        vr, ve, vs = anchor_knobs(t_raw)
        m_logical = t_raw
        dm_dvref = vref_sensitivity(params, vr, ve, vs)
    else:  # only per-row noise / drift active: no knob-space terms
        m_logical = jnp.float32(0.0)
        dm_dvref = jnp.float32(0.0)
    delta = _sample_deltas(
        key, noise,
        m_logical=m_logical,
        dm_dvref=dm_dvref,
        global_shape=shape[:-1],
        n_rows=shape[-1],
    )
    return t + delta


# ---------------------------------------------------------------------------
# SearchPhysics: schedule-aware physics for the Algorithm-1 ensemble head
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SearchPhysics:
    """AnalogParams + NoiseModel + per-pass knob provenance, bundled.

    The single source of truth for the Algorithm-1 threshold sweep under
    PVT noise: `sample()` is the only producer of effective per-pass HD
    thresholds consumed by `ensemble`, `pipeline`, and both kernels.

    thresholds : [P] float32 base HD-space thresholds (as deployed).
    m_logical  : [P] float32 logical tolerance per pass (knob-achieved) —
                 the magnitude the multiplicative strobe jitter acts on.
    dm_dvref   : [P] float32 d(m*)/dV_ref at each pass's knob point [HD/V].
    noise      : the PVT model; params: the analog constants (None when
                 the knob-space sigmas are inactive and never needed).
    """

    thresholds: jnp.ndarray
    m_logical: jnp.ndarray
    dm_dvref: jnp.ndarray
    noise: NoiseModel
    params: Optional[AnalogParams] = None

    @property
    def n_passes(self) -> int:
        """Passes in the Algorithm-1 threshold schedule."""
        return int(self.thresholds.shape[0])

    @property
    def is_noiseless(self) -> bool:
        """True when sampling returns the base thresholds bit-exactly."""
        return not self.noise.is_active

    @classmethod
    def for_sweep(
        cls,
        thresholds_hd,
        noise: NoiseModel = NOISELESS,
        params: Optional[AnalogParams] = None,
    ) -> "SearchPhysics":
        """Physics for an Algorithm-1 threshold schedule (HD space).

        Knob provenance: the Table-I-calibrated `knob_schedule` over the
        sweep's logical span (cached) when the schedule is equispaced
        (the paper's sweep; `knob_schedule` targets exactly that
        linspace); otherwise the nearest-Table-I-anchor fallback per
        pass.  The provenance is only computed when a knob-space sigma
        (vref / tjitter) is active; a pure sigma_hd / drift model — and
        the noiseless limit — skips it, and that path stays jit/vmap
        traceable with `thresholds_hd` as a traced array.  The
        knob-active path needs CONCRETE thresholds (the schedule
        inversion runs on host): prebuild the physics outside jit and
        pass it in (`votes_faithful(..., physics=...)`).
        """
        knob_active = bool(noise.sigma_vref or noise.sigma_tjitter)
        if not knob_active:
            t = jnp.asarray(thresholds_hd, jnp.float32)  # tracer-safe
            zero = jnp.zeros_like(t)
            return cls(thresholds=t, m_logical=zero, dm_dvref=zero,
                       noise=noise, params=params)
        if isinstance(thresholds_hd, jax.core.Tracer):
            raise TypeError(
                "SearchPhysics.for_sweep with sigma_vref/sigma_tjitter "
                "active needs concrete thresholds (host-side knob-"
                "schedule inversion); build the SearchPhysics outside "
                "jit and pass it via the physics= argument"
            )
        t = np.asarray(thresholds_hd, np.float32)
        span = float(t.max() - t.min()) if t.size else 0.0
        params = params or default_params()
        logical = t - (t.min() if t.size else 0.0)
        equispaced = t.size >= 2 and span > 0 and np.allclose(
            logical, np.linspace(0.0, span, t.size), atol=1e-3
        )
        if equispaced:
            knobs, achieved = _schedule_cached(t.size, int(round(span)))
            m_log = achieved
            dmdv = np.asarray(
                vref_sensitivity(
                    params, knobs[:, 0], knobs[:, 1], knobs[:, 2]
                ),
                np.float32,
            )
        else:  # degenerate / non-uniform sweep: nearest-anchor provenance
            vr, ve, vs = anchor_knobs(logical)
            m_log = np.asarray(logical, np.float32)
            dmdv = np.asarray(
                vref_sensitivity(params, vr, ve, vs), np.float32
            )
        return cls(
            thresholds=jnp.asarray(t, jnp.float32),
            m_logical=jnp.asarray(m_log, jnp.float32),
            dm_dvref=jnp.asarray(dmdv, jnp.float32),
            noise=noise,
            params=params,
        )

    @classmethod
    def for_head(
        cls,
        head,
        noise: NoiseModel = NOISELESS,
        params: Optional[AnalogParams] = None,
    ) -> "SearchPhysics":
        """Physics for a deployed `ensemble.CAMEnsembleHead`."""
        return cls.for_sweep(head.thresholds, noise, params)

    def sample(
        self,
        key: Optional[jax.Array],
        batch_shape: tuple = (),
        n_rows: int = 1,
    ) -> jnp.ndarray:
        """Sampled effective thresholds ``[P, *batch_shape, n_rows]``.

        Each (pass, batch element) is one silicon search cycle: the vref
        and strobe draws are shared across its `n_rows` rows; sigma_hd is
        drawn per row.  ``key=None`` or a noiseless model returns the base
        schedule broadcast — the bit-exact noiseless limit.
        """
        p = self.n_passes
        lead = (p,) + (1,) * len(batch_shape)
        base = self.thresholds.reshape(lead + (1,))
        shape = (p,) + tuple(batch_shape) + (n_rows,)
        if key is None or self.is_noiseless:
            return jnp.broadcast_to(base, shape)
        delta = _sample_deltas(
            key, self.noise,
            m_logical=self.m_logical.reshape(lead + (1,)),
            dm_dvref=self.dm_dvref.reshape(lead + (1,)),
            global_shape=(p,) + tuple(batch_shape),
            n_rows=n_rows,
        )
        return base + delta

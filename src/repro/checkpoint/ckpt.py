"""Atomic, async, elastic checkpointing for sharded pytrees.

Layout (one directory per step):
    <root>/step_000123/
        manifest.json      — leaf paths, shapes, dtypes, pytree structure,
                             step, config fingerprint, save wall-time
        <leaf-path>.npy    — one file per pytree leaf (host-gathered)

Properties:
  * ATOMIC   — written to `step_xxx.tmp-<nonce>/`, fsync'd, then renamed;
               a crash mid-save never corrupts the latest checkpoint.
  * ASYNC    — `save_async` snapshots device arrays to host memory
               synchronously (cheap) and writes files on a daemon thread,
               overlapping I/O with the next training steps.
  * ELASTIC  — restore() takes the *target* shardings: arrays are loaded
               host-side and device_put against whatever mesh/sharding the
               restarted job uses — a 2-pod checkpoint restores onto 1 pod
               or 4 pods unchanged (full-array .npy storage; per-shard
               storage with resharding-on-read is the documented scale-up
               path, see DESIGN.md).
  * RETAINED — keep_last prunes old steps after a successful save.

This module is deliberately dependency-free (no orbax) — the container is
offline, and the dry-run only needs the semantics, which the FT tests
exercise end to end (kill/restore/elastic-reshard).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import uuid
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, leaf))
    return out


def save(root: Path, step: int, tree, *, keep_last: int = 3) -> Path:
    """Synchronous atomic save. Returns the final checkpoint directory."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f".step_{step:08d}.tmp-{uuid.uuid4().hex[:8]}"
    tmp.mkdir(parents=True)
    leaves = _leaf_paths(tree)
    manifest = {"step": step, "time": time.time(), "leaves": []}
    for name, leaf in leaves:
        arr = np.asarray(leaf)
        fname = name.replace("/", "__") + ".npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            {"path": name, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)}
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    # fsync the directory entries before the atomic publish
    fd = os.open(tmp, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(root, keep_last)
    return final


class AsyncCheckpointer:
    """Snapshot-on-call, write-on-thread checkpointing."""

    def __init__(self, root: Path, keep_last: int = 3):
        self.root = Path(root)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save_async(self, step: int, tree):
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot

        def work():
            try:
                save(self.root, step, host_tree, keep_last=self.keep_last)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err


def _prune(root: Path, keep_last: int):
    steps = sorted(p for p in root.glob("step_*") if p.is_dir())
    for p in steps[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(root: Path) -> Optional[int]:
    root = Path(root)
    if not root.exists():
        return None
    steps = sorted(p.name for p in root.glob("step_*") if p.is_dir())
    if not steps:
        return None
    return int(steps[-1].split("_")[1])


def restore(
    root: Path,
    step: Optional[int],
    target_tree,
    shardings=None,
):
    """Load a checkpoint into the structure (and shardings) of target_tree.

    target_tree — pytree of arrays or ShapeDtypeStructs (the template).
    shardings   — optional matching pytree of NamedShardings; arrays are
                  device_put against them (elastic restore onto any mesh).
    """
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    by_path = {e["path"]: e for e in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_flat = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None
        else [None] * len(flat)
    )
    out = []
    for (path, leaf), shd in zip(flat, shard_flat):
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        entry = by_path.get(name)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = np.load(d / entry["file"])
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs {expect}"
            )
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]

"""The unified decoder-only model covering all ten assigned architectures.

One definition, driven entirely by ModelConfig:
  * dense / GQA transformers (stablelm, llama3.2, starcoder2, llama3-405b,
    chameleon, musicgen)
  * MoE transformers (mixtral, llama4-maverick)
  * attention-free SSM (falcon-mamba)
  * hybrid interleaves (jamba: 1 attn : 7 mamba, MoE every other layer)

Layer stacks are scanned (jax.lax.scan over stacked params) in units of
the config's LayerPattern "superblock" — homogeneous archs scan single
layers; jamba scans 8-sublayer superblocks; llama4 scans 4-sublayer
(3 local + 1 global attention) superblocks.  Scanning keeps the HLO (and
compile time) independent of depth, which is what makes the 126-layer
llama3-405b dry-run tractable.

Entry points:
  init_params / param_axes  — parameter pytree + logical shardings
  forward                   — [B, S] tokens -> [B, S, V] logits (training)
  loss_fn                   — chunked-vocab cross entropy (+ MoE aux)
  init_cache / prefill / decode — serving paths
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.sharding import shard, logical_to_spec

F32 = jnp.float32
FULL_WINDOW = 1 << 30

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_sublayer(cfg: ModelConfig, kind: str, use_moe: bool, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"norm1": L.init_norm(cfg, k1)}
    if kind == "attn":
        p["attn"] = L.init_attention(cfg, k2)
        p["norm2"] = L.init_norm(cfg, k3)
        p["ffn"] = L.init_moe(cfg, k4) if use_moe else L.init_mlp(cfg, k4)
    elif kind == "mamba":
        p["mamba"] = S.init_mamba(cfg, k2)
        if cfg.family in ("hybrid",):  # jamba: mamba sublayers carry an FFN
            p["norm2"] = L.init_norm(cfg, k3)
            p["ffn"] = L.init_moe(cfg, k4) if use_moe else L.init_mlp(cfg, k4)
    else:
        raise ValueError(kind)
    return p


def _init_block(cfg: ModelConfig, key):
    pat = cfg.pattern()
    keys = jax.random.split(key, pat.size)
    return {
        f"sub{i}": _init_sublayer(cfg, pat.kinds[i], pat.moe_mask[i], keys[i])
        for i in range(pat.size)
    }


def init_params(cfg: ModelConfig, key) -> Params:
    ke, kb, kh, kn = jax.random.split(key, 4)
    dt = cfg.jax_dtype
    embed = (
        jax.random.normal(ke, (cfg.vocab_size, cfg.d_model), dt)
        * cfg.d_model**-0.5
    )
    block_keys = jax.random.split(kb, cfg.blocks)
    blocks = jax.vmap(lambda k: _init_block(cfg, k))(block_keys)
    p: Params = {
        "embed": embed,
        "blocks": blocks,
        "final_norm": L.init_norm(cfg, kn),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (
            jax.random.normal(kh, (cfg.d_model, cfg.vocab_size), dt)
            * cfg.d_model**-0.5
        )
    if cfg.cam_head:
        from repro.models import binary_lm

        p["cam_head"] = binary_lm.init_cam_head(cfg, kh)
    return p


def _sublayer_axes(cfg: ModelConfig, kind: str, use_moe: bool):
    norm_ax = {"scale": (None,)}
    if cfg.norm == "layernorm":
        norm_ax["bias"] = (None,)
    p = {"norm1": norm_ax}
    if kind == "attn":
        p["attn"] = L.attention_param_axes(cfg)
        p["norm2"] = norm_ax
        p["ffn"] = L.moe_param_axes(cfg) if use_moe else L.mlp_param_axes(cfg)
    else:
        p["mamba"] = S.mamba_param_axes(cfg)
        if cfg.family in ("hybrid",):
            p["norm2"] = norm_ax
            p["ffn"] = (
                L.moe_param_axes(cfg) if use_moe else L.mlp_param_axes(cfg)
            )
    return p


def param_axes(cfg: ModelConfig) -> Params:
    """Pytree of logical-axis tuples mirroring init_params' structure.

    Stacked block params get a leading None (blocks dim is never sharded)."""
    pat = cfg.pattern()
    blocks = {
        f"sub{i}": jax.tree_util.tree_map(
            lambda ax: (None,) + tuple(ax),
            _sublayer_axes(cfg, pat.kinds[i], pat.moe_mask[i]),
            is_leaf=lambda x: isinstance(x, tuple),
        )
        for i in range(pat.size)
    }
    p: Params = {
        "embed": ("p_embed_v", "p_embed_d"),
        "blocks": blocks,
        "final_norm": {"scale": (None,)}
        | ({"bias": (None,)} if cfg.norm == "layernorm" else {}),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = ("p_mlp_d", "p_vocab")
    if cfg.cam_head:
        from repro.models import binary_lm

        p["cam_head"] = binary_lm.cam_head_axes(cfg)
    return p


def param_pspecs(cfg: ModelConfig, rules) -> Params:
    """PartitionSpec pytree for in_shardings (dry-run / checkpoint)."""
    axes = param_axes(cfg)
    return jax.tree_util.tree_map(
        lambda ax: rules.spec(*ax), axes, is_leaf=lambda x: isinstance(x, tuple)
    )


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _run_sublayer(
    p,
    cfg: ModelConfig,
    kind: str,
    use_moe: bool,
    window: Optional[int],
    h,
    positions,
    inv_freq,
    cache: Optional[dict],
    cache_index,
    aux: Optional[dict],
):
    x = L.apply_norm(p["norm1"], cfg, h)
    new_cache = None
    if kind == "attn":
        w = FULL_WINDOW if window is None else window
        y, new_cache = L.attention(
            p["attn"], cfg, x, positions, inv_freq,
            window=w, cache=cache, cache_index=cache_index,
        )
        h = h + y
        x2 = L.apply_norm(p["norm2"], cfg, h)
        if use_moe:
            y2 = L.moe(p["ffn"], cfg, x2, aux=aux)
        else:
            y2 = L.mlp(p["ffn"], cfg, x2)
        h = h + y2
    else:
        y, new_cache = S.mamba_block(p["mamba"], cfg, x, cache=cache)
        h = h + y
        if "ffn" in p:
            x2 = L.apply_norm(p["norm2"], cfg, h)
            if use_moe:
                y2 = L.moe(p["ffn"], cfg, x2, aux=aux)
            else:
                y2 = L.mlp(p["ffn"], cfg, x2)
            h = h + y2
    return h, new_cache


def _block_fn(
    cfg: ModelConfig,
    block_params,
    h,
    positions,
    inv_freq,
    block_cache,
    cache_index,
    collect_aux: bool,
):
    """One scan step: runs every sublayer of the pattern."""
    pat = cfg.pattern()
    new_cache = {}
    aux = {"moe_aux": jnp.zeros((), F32)} if collect_aux else None
    for i in range(pat.size):
        sub = f"sub{i}"
        c = block_cache.get(sub) if block_cache is not None else None
        h, nc = _run_sublayer(
            block_params[sub],
            cfg,
            pat.kinds[i],
            pat.moe_mask[i],
            pat.windows[i],
            h,
            positions,
            inv_freq,
            c,
            cache_index,
            aux,
        )
        if nc is not None:
            new_cache[sub] = nc
    aux_out = aux["moe_aux"] if collect_aux else jnp.zeros((), F32)
    return h, (new_cache if new_cache else None), aux_out


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def _stack(cfg, params, h, positions, cache, cache_index, collect_aux):
    """Scan the block stack. cache: stacked [blocks, ...] pytree or None."""
    inv_freq = L.rope_frequencies(cfg)

    def body(carry, xs):
        h, aux_sum = carry
        block_params, block_cache = xs
        # sequence-parallel residual carry (no-op unless the active rules
        # map "act_seq" to a mesh axis — see TRAIN_SP_RULES)
        h = shard(h, "batch", "act_seq", "embed")
        h, new_cache, aux = _block_fn(
            cfg, block_params, h, positions, inv_freq,
            block_cache, cache_index, collect_aux,
        )
        return (h, aux_sum + aux), new_cache

    body = _remat_wrap(cfg, body)
    (h, aux_sum), new_cache = jax.lax.scan(
        body, (h, jnp.zeros((), F32)), (params["blocks"], cache)
    )
    return h, new_cache, aux_sum


def _embed_in(params, cfg: ModelConfig, tokens, embeds):
    if embeds is not None:
        h = embeds.astype(cfg.jax_dtype)
    else:
        h = jnp.take(params["embed"], tokens, axis=0)
    return shard(h, "batch", "seq", "embed")


def _lm_head(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens=None,
    embeds=None,
    positions=None,
    collect_aux: bool = False,
):
    """Training-mode forward: full-sequence logits [B, S, V] (bf16)."""
    b, s = (tokens.shape if tokens is not None else embeds.shape[:2])
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = _embed_in(params, cfg, tokens, embeds)
    h, _, aux = _stack(cfg, params, h, positions, None, None, collect_aux)
    h = L.apply_norm(params["final_norm"], cfg, h)
    logits = jnp.einsum(
        "bsd,dv->bsv", h, _lm_head(params, cfg), preferred_element_type=F32
    )
    return shard(logits, "batch", "seq", "vocab"), aux


def chunked_loss(
    params: Params,
    cfg: ModelConfig,
    h,
    labels,
    chunk: int = 512,
):
    """Cross entropy with the [B, chunk, V] logits tensor bounded.

    The full-sequence logits of a 200k-vocab model at 1M tokens would be
    ~0.8 TB in f32; chunking the sequence bounds the live logits tensor
    while remat recomputes per-chunk activations in the backward pass.
    """
    b, s, d = h.shape
    head = _lm_head(params, cfg)
    n_chunks = s // chunk if s % chunk == 0 else 1
    if s % chunk != 0:
        chunk = s
        n_chunks = 1
    hc = h.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def body(tot, xs):
        h_i, l_i = xs
        logits = jnp.einsum(
            "bsd,dv->bsv", h_i, head, preferred_element_type=F32
        )
        logits = shard(logits, "batch", "seq", "vocab")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, l_i[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        return tot + (logz - gold).sum(), None

    body = _remat_wrap(cfg, body)
    total, _ = jax.lax.scan(body, jnp.zeros((), F32), (hc, lc))
    return total / (b * s)


def loss_fn(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    aux_weight: float = 0.01,
):
    """batch: {"tokens" | "embeds", "labels"} -> scalar loss (+ metrics)."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    b, s = (tokens.shape if tokens is not None else embeds.shape[:2])
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = _embed_in(params, cfg, tokens, embeds)
    collect_aux = cfg.n_experts > 0
    h, _, aux = _stack(cfg, params, h, positions, None, None, collect_aux)
    h = L.apply_norm(params["final_norm"], cfg, h)
    ce = chunked_loss(params, cfg, h, batch["labels"])
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------
def _attn_cache(cfg: ModelConfig, batch: int, max_len: int, window):
    length = max_len if window is None else min(window, max_len)
    g, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, length, g, hd), cfg.jax_dtype),
        "v": jnp.zeros((batch, length, g, hd), cfg.jax_dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked [blocks, ...] cache pytree for decode."""
    pat = cfg.pattern()

    def one_block(_):
        c = {}
        for i in range(pat.size):
            if pat.kinds[i] == "attn":
                c[f"sub{i}"] = _attn_cache(cfg, batch, max_len, pat.windows[i])
            else:
                c[f"sub{i}"] = S.init_mamba_cache(cfg, batch)
        return c

    cache = jax.vmap(one_block)(jnp.arange(cfg.blocks))
    return cache


def cache_axes(cfg: ModelConfig):
    """Logical axes for the cache pytree (leading blocks dim unsharded)."""
    pat = cfg.pattern()
    blocks = {}
    for i in range(pat.size):
        if pat.kinds[i] == "attn":
            blocks[f"sub{i}"] = {
                "k": (None, "batch", "kv_seq", "kv_heads", None),
                "v": (None, "batch", "kv_seq", "kv_heads", None),
                "pos": (None, "batch", "kv_seq"),
            }
        else:
            blocks[f"sub{i}"] = {
                "conv": (None, "batch", None, "mlp"),
                "h": (None, "batch", "mlp", None),
            }
    return blocks


def cache_pspecs(cfg: ModelConfig, rules):
    axes = cache_axes(cfg)
    return jax.tree_util.tree_map(
        lambda ax: rules.spec(*ax), axes, is_leaf=lambda x: isinstance(x, tuple)
    )


def prefill(
    params: Params, cfg: ModelConfig, tokens=None, embeds=None,
    max_len: int | None = None,
):
    """Process the prompt; return (last-position logits [B, V], cache).

    max_len sizes the cache (>= prompt length); decode steps beyond it
    roll (window semantics).  Default: prompt length + 64 decode slots.
    """
    b, s = (tokens.shape if tokens is not None else embeds.shape[:2])
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cache = init_cache(cfg, b, max_len if max_len is not None else s + 64)
    h = _embed_in(params, cfg, tokens, embeds)
    h, new_cache, _ = _stack(cfg, params, h, positions, cache, None, False)
    h = L.apply_norm(params["final_norm"], cfg, h[:, -1:, :])
    logits = jnp.einsum(
        "bsd,dv->bsv", h, _lm_head(params, cfg), preferred_element_type=F32
    )[:, 0]
    return shard(logits, "batch", "vocab"), new_cache


def decode(params: Params, cfg: ModelConfig, cache, tokens, pos):
    """One decode step.

    tokens: [B, 1] int32 (or embeds [B, 1, D] when cfg.embeds_input);
    pos: scalar int32 — the absolute position of the new token (uniform
    across the batch; per-row offsets are handled by the serving engine).
    Returns (logits [B, V], new_cache).
    """
    if cfg.embeds_input and tokens.ndim == 3:
        h = tokens.astype(cfg.jax_dtype)
        b = h.shape[0]
    else:
        b = tokens.shape[0]
        h = jnp.take(params["embed"], tokens, axis=0)
    h = shard(h, "batch", "seq", "embed")
    positions = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32)[None, None], (b, 1)
    )
    h, new_cache, _ = _stack(cfg, params, h, positions, cache, pos, False)
    h = L.apply_norm(params["final_norm"], cfg, h)
    if cfg.cam_head:
        from repro.models import binary_lm

        logits = binary_lm.cam_head_logits(params["cam_head"], cfg, h[:, 0])
    else:
        logits = jnp.einsum(
            "bsd,dv->bsv", h, _lm_head(params, cfg),
            preferred_element_type=F32,
        )[:, 0]
    return shard(logits, "batch", "vocab"), new_cache

"""LM substrate: one unified decoder-only model covering dense / MoE /
SSM / hybrid architectures, plus the paper's binary-LM integration."""

from repro.models import layers, model, ssm, binary_lm  # noqa: F401

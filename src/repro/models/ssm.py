"""Mamba-1 (selective state space) block — the attention-free substrate
for falcon-mamba-7b and the mamba sublayers of jamba.

Layout per block (Gu & Dao 2023, mamba_simple):
    x  --in_proj--> [x1 | z]           (d_model -> 2 * d_inner)
    x1 --causal depthwise conv(k=4)--> silu
    x1 --x_proj--> [dt_lowrank | B | C]
    dt = softplus(dt_lowrank @ dt_proj + dt_bias)          [*, d_inner]
    h_t = exp(dt*A) * h_{t-1} + dt * B_t * x_t             (selective scan)
    y   = C_t . h_t + D * x1
    out = (y * silu(z)) @ out_proj

TPU adaptation notes (DESIGN.md §2): the CUDA kernel's SRAM-fused scan
becomes a jax.lax.scan over time with the [B, d_inner, N] state held in
VMEM-resident carry; the O(S) recurrence is exact.  A chunked (SSD-style)
matmul formulation is the hillclimb alternative when the sequential scan
is latency-bound on real hardware.

Decode is O(1): one state update per token, conv ring buffer of k-1 taps.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import shard

F32 = jnp.float32

# time-axis chunk of the two-level selective scan (memory/recompute knob)
_SCAN_CHUNK = 256


def init_mamba(cfg: ModelConfig, key):
    d, din, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    r, kc = cfg.dt_rank, cfg.ssm_conv
    dt = cfg.jax_dtype
    keys = jax.random.split(key, 6)
    s = d**-0.5
    # S4D-real initialization for A: A_log = log(1..N) broadcast over d_inner
    a_init = jnp.log(jnp.arange(1, n + 1, dtype=F32))
    return {
        "in_proj": jax.random.normal(keys[0], (d, 2 * din), dt) * s,
        "conv_w": jax.random.normal(keys[1], (kc, din), dt) * (kc**-0.5),
        "conv_b": jnp.zeros((din,), dt),
        "x_proj": jax.random.normal(keys[2], (din, r + 2 * n), dt) * (din**-0.5),
        "dt_proj": jax.random.normal(keys[3], (r, din), dt) * (r**-0.5),
        "dt_bias": jnp.full((din,), -4.6, dt),  # softplus^-1(0.01)
        "A_log": jnp.broadcast_to(a_init, (din, n)).astype(F32) + 0.0,
        "D": jnp.ones((din,), F32),
        "out_proj": jax.random.normal(keys[4], (din, d), dt) * (din**-0.5),
    }


def mamba_param_axes(cfg: ModelConfig):
    return {
        "in_proj": ("p_ssm_d", "p_ssm_inner"),
        "conv_w": (None, "p_ssm_inner"),
        "conv_b": ("p_ssm_inner",),
        "x_proj": ("p_ssm_inner", None),
        "dt_proj": (None, "p_ssm_inner"),
        "dt_bias": ("p_ssm_inner",),
        "A_log": ("p_ssm_inner", None),
        "D": ("p_ssm_inner",),
        "out_proj": ("p_ssm_inner", "p_ssm_d"),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=None):
    dtype = dtype or cfg.jax_dtype
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), F32),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv along S. x: [B, S, din], w: [kc, din].

    conv_state: [B, kc-1, din] — the trailing inputs from the previous
    segment (decode ring buffer); zeros for training.
    Returns (y [B, S, din], new_state [B, kc-1, din]).
    """
    bsz, s, din = x.shape
    kc = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((bsz, kc - 1, din), x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    # y[t] = sum_j w[j] * xp[t + j]; implemented as shifted adds (kc = 4)
    y = jnp.zeros((bsz, s, din), F32)
    for j in range(kc):
        y = y + xp[:, j : j + s, :].astype(F32) * w[j].astype(F32)
    y = y + b.astype(F32)
    new_state = xp[:, -(kc - 1) :, :] if kc > 1 else conv_state
    return y.astype(x.dtype), new_state


def mamba_block(
    p,
    cfg: ModelConfig,
    x,
    *,
    cache: Optional[dict] = None,
):
    """x: [B, S, D] -> ([B, S, D], new_cache or None)."""
    bsz, s, d = x.shape
    din, n, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank

    xz = jnp.einsum(
        "bsd,de->bse", x, p["in_proj"], preferred_element_type=F32
    ).astype(x.dtype)
    x1, z = jnp.split(xz, 2, axis=-1)
    x1 = shard(x1, "batch", "seq", "mlp")

    conv_state = cache["conv"] if cache is not None else None
    x1, new_conv = _causal_conv(x1, p["conv_w"], p["conv_b"], conv_state)
    x1 = jax.nn.silu(x1.astype(F32)).astype(x.dtype)

    xdbc = jnp.einsum(
        "bse,ef->bsf", x1, p["x_proj"], preferred_element_type=F32
    )
    dt_low, bmat, cmat = jnp.split(xdbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_low, p["dt_proj"].astype(F32))
        + p["dt_bias"].astype(F32)
    )  # [B, S, din] f32
    a = -jnp.exp(p["A_log"])  # [din, N] f32

    h0 = (
        cache["h"]
        if cache is not None
        else jnp.zeros((bsz, din, n), F32)
    )

    if s == 1:
        # decode fast path: single state update, no scan
        dt0 = dt[:, 0]  # [B, din]
        da = jnp.exp(dt0[:, :, None] * a)  # [B, din, N]
        hb = da * h0 + dt0[:, :, None] * bmat[:, 0][:, None, :] * x1[
            :, 0
        ].astype(F32)[:, :, None]
        y = (hb * cmat[:, 0][:, None, :]).sum(-1)[:, None, :]  # [B, 1, din]
        h_last = hb
    else:
        # Training / prefill: TWO-LEVEL sequential scan.  Naive scan-AD
        # saves the [B, din, N] state at EVERY step (S x 8 MB per layer —
        # terabytes at 4k context); chunking the time axis and remat-ing
        # the chunk body keeps only S/chunk boundary states plus one
        # chunk of in-flight residuals — the JAX analogue of the mamba
        # CUDA kernel's backward recomputation.
        chunk = min(_SCAN_CHUNK, s)
        pad = (-s) % chunk
        n_chunks = (s + pad) // chunk

        def pad_t(x):  # [B, S, ...] -> [n_chunks, chunk, B, ...]
            if pad:
                x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
            x = x.swapaxes(0, 1).reshape(n_chunks, chunk, *x.shape[:1],
                                         *x.shape[2:])
            return x

        seq = (
            pad_t(dt),
            pad_t(bmat.astype(F32)),
            pad_t(cmat.astype(F32)),
            pad_t(x1.astype(F32)),
        )

        def step(h, inputs):
            dt_t, b_t, c_t, x_t = inputs  # [B,din],[B,N],[B,N],[B,din]
            da = jnp.exp(dt_t[:, :, None] * a)
            h = da * h + dt_t[:, :, None] * b_t[:, None, :] * x_t[:, :, None]
            y_t = (h * c_t[:, None, :]).sum(-1)  # [B, din]
            return h, y_t

        @jax.checkpoint
        def chunk_body(h, chunk_inputs):
            return jax.lax.scan(step, h, chunk_inputs)

        h_last, ys = jax.lax.scan(chunk_body, h0, seq)
        y = ys.reshape(n_chunks * chunk, bsz, din)[:s].swapaxes(0, 1)

    y = y + p["D"].astype(F32) * x1.astype(F32)
    y = (y * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    y = shard(y, "batch", "seq", "mlp")
    out = jnp.einsum(
        "bse,ed->bsd", y, p["out_proj"], preferred_element_type=F32
    ).astype(x.dtype)
    out = shard(out, "batch", "seq", "embed")

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "h": h_last}
    return out, new_cache

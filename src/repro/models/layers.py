"""Transformer substrate: norms, RoPE, GQA flash attention, MLP, MoE.

All layers are pure functions over parameter dicts (pytrees), so layer
stacks can be jax.lax.scan'ed over stacked parameters.  Sharding is
annotated through logical axis names (repro.sharding); the same code
serves every parallelism layout.

Numerics: parameters live in cfg.jax_dtype (bf16 for the full configs);
matmuls accumulate in f32 (preferred_element_type); softmax/norms in f32.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import shard

F32 = jnp.float32


def _matmul(x, w):
    """bf16-in f32-accumulate matmul, result cast back to x.dtype."""
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())), preferred_element_type=F32
    )
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, key=None):
    p = {"scale": jnp.ones((cfg.d_model,), cfg.jax_dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), cfg.jax_dtype)
    return p


def apply_norm(p, cfg: ModelConfig, x):
    xf = x.astype(F32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6)
        return (y * p["scale"].astype(F32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    return (y * p["scale"].astype(F32) + p["bias"].astype(F32)).astype(x.dtype)


def _head_norm(x):
    """Per-head RMS norm (chameleon QK-norm), no learned scale."""
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(cfg: ModelConfig):
    half = cfg.head_dim // 2
    return cfg.rope_theta ** (-jnp.arange(0, half, dtype=F32) / half)


def apply_rope(x, positions, inv_freq):
    """x: [B, S, H, dh]; positions: [B, S] (or [S]) int32."""
    angles = positions[..., None].astype(F32) * inv_freq  # [B, S, half]
    cos = jnp.cos(angles)[..., None, :]  # [B, S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, flash-style chunked online softmax, sliding window)
# ---------------------------------------------------------------------------
def init_attention(cfg: ModelConfig, key):
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d**-0.5
    dt = cfg.jax_dtype
    return {
        "wq": (jax.random.normal(k1, (d, hq, hd), dt) * s),
        "wk": (jax.random.normal(k2, (d, hkv, hd), dt) * s),
        "wv": (jax.random.normal(k3, (d, hkv, hd), dt) * s),
        "wo": (jax.random.normal(k4, (hq, hd, d), dt) * s),
    }


def attention_param_axes(cfg: ModelConfig):
    return {
        "wq": ("p_attn_d", "p_attn_heads", None),
        "wk": ("p_attn_d", "p_attn_heads", None),
        "wv": ("p_attn_d", "p_attn_heads", None),
        "wo": ("p_attn_heads", None, "p_attn_d"),
    }


def _flash_attention(q, k, v, q_pos, k_pos, window, chunk: int):
    """Chunked online-softmax attention with causal + window masking.

    q      : [B, G, R, Sq, dh]   (G = kv groups, R = heads per group)
    k, v   : [B, G, Sk, dh]
    q_pos  : [B, Sq] int32 absolute positions of the queries
    k_pos  : [B, Sk] int32 absolute positions of the keys (-1 = invalid)
    window : int or traced scalar; attend iff 0 <= qp - kp < window
    Returns [B, G, R, Sq, dh] in q.dtype.
    """
    b, g, r, sq, dh = q.shape
    sk = k.shape[2]
    scale = dh**-0.5
    nchunk = -(-sk // chunk)
    pad = nchunk * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    # reshape into chunks for the scan: [nchunk, B, G, chunk, dh]
    kc = k.reshape(b, g, nchunk, chunk, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, g, nchunk, chunk, dh).transpose(2, 0, 1, 3, 4)
    pc = k_pos.reshape(b, nchunk, chunk).transpose(1, 0, 2)

    qf = q.astype(F32) * scale
    neg = jnp.float32(-1e30)

    # Remat the chunk body: without this, scan-AD stacks the per-chunk
    # score matrices p [B,G,R,Sq,chunk] as residuals — the full S^2
    # attention matrix in HBM, exactly what flash attention exists to
    # avoid.  With it, backward recomputes p from (q, k-chunk); only the
    # (m, l, acc) carries are stacked: S*dh instead of S^2 per head.
    @jax.checkpoint
    def body(carry, inputs):
        m, l, acc = carry
        k_i, v_i, kp_i = inputs
        # scores: [B, G, R, Sq, chunk]
        s = jnp.einsum(
            "bgrqd,bgcd->bgrqc", qf, k_i.astype(F32),
            preferred_element_type=F32,
        )
        delta = q_pos[:, None, None, :, None] - kp_i[:, None, None, None, :]
        valid = (delta >= 0) & (delta < window) & (
            kp_i[:, None, None, None, :] >= 0
        )
        s = jnp.where(valid, s, neg)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        correction = jnp.exp(m - m_new)
        l_new = l * correction + p.sum(-1)
        acc_new = acc * correction[..., None] + jnp.einsum(
            "bgrqc,bgcd->bgrqd", p, v_i.astype(F32),
            preferred_element_type=F32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, g, r, sq), neg, F32)
    l0 = jnp.zeros((b, g, r, sq), F32)
    a0 = jnp.zeros((b, g, r, sq, dh), F32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def attention(
    p,
    cfg: ModelConfig,
    h,
    positions,
    inv_freq,
    *,
    window,
    cache: Optional[dict] = None,
    cache_index=None,
):
    """GQA attention sublayer (post-norm input h: [B, S, D]).

    Training / prefill: cache is None or a to-be-filled cache dict; the
    full [B, S] key/value set is used via the flash path.
    Decode: S == 1; cache holds past KV (+ absolute positions); the new
    KV is written at slot cache_index % cache_len (rolling for windows).

    Returns (out [B, S, D], new_cache or None).
    """
    b, s, d = h.shape
    g, r = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    hd = cfg.head_dim

    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"], preferred_element_type=F32)
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"], preferred_element_type=F32)
    v = jnp.einsum(
        "bsd,dhk->bshk", h, p["wv"], preferred_element_type=F32
    ).astype(h.dtype)
    if cfg.qk_norm:
        q, k = _head_norm(q), _head_norm(k)
    q = apply_rope(q.astype(h.dtype), positions, inv_freq)
    k = apply_rope(k.astype(h.dtype), positions, inv_freq)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    qg = q.reshape(b, s, g, r, hd).transpose(0, 2, 3, 1, 4)  # [B,G,R,S,dh]

    new_cache = None
    if cache is not None and s == 1:
        # ---- decode: write new kv into the (rolling) cache ----
        cache_len = cache["k"].shape[1]
        slot = (cache_index % cache_len).astype(jnp.int32)
        k_c = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0)
        )
        v_c = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0)
        )
        pos_new = jnp.broadcast_to(positions.astype(jnp.int32), (b, 1))
        pos_c = jax.lax.dynamic_update_slice(cache["pos"], pos_new, (0, slot))
        new_cache = {"k": k_c, "v": v_c, "pos": pos_c}
        # q_len == 1: direct attention over the cache IN ITS STORED LAYOUT
        # [B, L, G, dh].  The previous flash path transposed + re-chunked
        # the whole cache every step (3x full-cache copies per layer,
        # measured at 15 TB/step on musicgen decode_32k); reading it once
        # through the einsum is the roofline-minimal access pattern.
        scale = hd**-0.5
        qf = (qg.astype(F32) * scale).astype(qg.dtype)  # [B, G, R, 1, dh]
        # keep the CACHE operand in bf16 — an explicit astype(F32) would
        # materialize an f32 copy of the whole cache (2x its size in HBM
        # traffic per step); the MXU accumulates in f32 regardless via
        # preferred_element_type.
        scores = jnp.einsum(
            "bgrqd,blgd->bgrql", qf, k_c,
            preferred_element_type=F32,
        )
        delta = (
            positions.astype(jnp.int32)[:, 0][:, None, None, None, None]
            - pos_c[:, None, None, None, :]
        )
        valid = (delta >= 0) & (delta < window) & (
            pos_c[:, None, None, None, :] >= 0
        )
        scores = jnp.where(valid, scores, jnp.float32(-1e30))
        probs = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
        out = jnp.einsum(
            "bgrql,blgd->bgrqd", probs, v_c,
            preferred_element_type=F32,
        ).astype(h.dtype)
    else:
        # ---- train / prefill over the in-context keys ----
        if cache is not None:
            # prefill writes the cache (rolling if window < S)
            cache_len = cache["k"].shape[1]
            if cache_len >= s:
                k_c = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)
                )
                v_c = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)
                )
                pos_c = jax.lax.dynamic_update_slice(
                    cache["pos"],
                    jnp.broadcast_to(positions.astype(jnp.int32), (b, s)),
                    (0, 0),
                )
            else:  # keep the last cache_len positions (rolling window)
                # slot convention: position p lives at slot p % cache_len
                # (decode's dynamic_update_slice relies on it) — roll the
                # trailing window so slots line up with that mapping.
                shift = (s - cache_len) % cache_len
                k_c = jnp.roll(
                    k[:, -cache_len:].astype(cache["k"].dtype), shift, axis=1
                )
                v_c = jnp.roll(
                    v[:, -cache_len:].astype(cache["v"].dtype), shift, axis=1
                )
                pos_c = jnp.roll(
                    jnp.broadcast_to(
                        positions.astype(jnp.int32), (b, s)
                    )[:, -cache_len:],
                    shift,
                    axis=1,
                )
            new_cache = {"k": k_c, "v": v_c, "pos": pos_c}
        kk = k.transpose(0, 2, 1, 3)
        vv = v.transpose(0, 2, 1, 3)
        kpos = jnp.broadcast_to(positions.astype(jnp.int32), (b, s))
        out = _flash_attention(
            qg, kk, vv, kpos, kpos, window, chunk=min(cfg.attn_chunk, s)
        )

    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, g * r, hd)
    out = shard(out, "batch", "seq", "heads", None)
    # the out-projection contracts the TP-sharded head dim: its partial
    # sums are what GSPMD all-reduces — bf16 output halves that wire
    y = jnp.einsum(
        "bshk,hkd->bsd", out, p["wo"],
        preferred_element_type=(h.dtype if cfg.tp_ar_bf16 else F32),
    ).astype(h.dtype)
    return shard(y, "batch", "seq", "embed"), new_cache


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, key):
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.jax_dtype
    s_in, s_out = d**-0.5, f**-0.5
    if cfg.mlp_act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": jax.random.normal(k1, (d, f), dt) * s_in,
            "w_up": jax.random.normal(k2, (d, f), dt) * s_in,
            "w_down": jax.random.normal(k3, (f, d), dt) * s_out,
        }
    k1, k2 = jax.random.split(key)
    return {
        "w_in": jax.random.normal(k1, (d, f), dt) * s_in,
        "w_out": jax.random.normal(k2, (f, d), dt) * s_out,
    }


def mlp_param_axes(cfg: ModelConfig):
    if cfg.mlp_act == "swiglu":
        return {
            "w_gate": ("p_mlp_d", "p_mlp_f"),
            "w_up": ("p_mlp_d", "p_mlp_f"),
            "w_down": ("p_mlp_f", "p_mlp_d"),
        }
    return {"w_in": ("p_mlp_d", "p_mlp_f"), "w_out": ("p_mlp_f", "p_mlp_d")}


def mlp(p, cfg: ModelConfig, h):
    if cfg.binary_ffn:
        from repro.models.binary_lm import bitlinear_mlp

        return bitlinear_mlp(p, cfg, h)
    down_t = h.dtype if cfg.tp_ar_bf16 else F32

    def _down(x, w):  # TP-contracting projection (see attention note)
        y = jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=down_t,
        )
        return y.astype(h.dtype)

    if cfg.mlp_act == "swiglu":
        gate = _matmul(h, p["w_gate"])
        up = _matmul(h, p["w_up"])
        act = shard(jax.nn.silu(gate) * up, "batch", "seq", "mlp")
        return shard(_down(act, p["w_down"]), "batch", "seq", "embed")
    act = jax.nn.gelu(_matmul(h, p["w_in"]))
    act = shard(act, "batch", "seq", "mlp")
    return shard(_down(act, p["w_out"]), "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# MoE (top-k routing, capacity-bounded scatter dispatch, GShard-style)
# ---------------------------------------------------------------------------
def init_moe(cfg: ModelConfig, key):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.jax_dtype
    s_in, s_out = d**-0.5, f**-0.5
    k0, k1, k2, k3 = jax.random.split(key, 4)
    p = {"router": jax.random.normal(k0, (d, e), dt) * s_in}
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = jax.random.normal(k1, (e, d, f), dt) * s_in
        p["w_up"] = jax.random.normal(k2, (e, d, f), dt) * s_in
        p["w_down"] = jax.random.normal(k3, (e, f, d), dt) * s_out
    else:
        p["w_in"] = jax.random.normal(k1, (e, d, f), dt) * s_in
        p["w_out"] = jax.random.normal(k2, (e, f, d), dt) * s_out
    return p


def moe_param_axes(cfg: ModelConfig):
    if cfg.mlp_act == "swiglu":
        return {
            "router": (None, None),
            "w_gate": ("p_expert", "p_mlp_d", "p_mlp_f"),
            "w_up": ("p_expert", "p_mlp_d", "p_mlp_f"),
            "w_down": ("p_expert", "p_mlp_f", "p_mlp_d"),
        }
    return {
        "router": (None, None),
        "w_in": ("p_expert", "p_mlp_d", "p_mlp_f"),
        "w_out": ("p_expert", "p_mlp_f", "p_mlp_d"),
    }


def moe_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.moe_top_k / cfg.n_experts)
    return max(c, cfg.moe_top_k)


def moe(p, cfg: ModelConfig, h, *, aux: Optional[dict] = None):
    """Capacity-bounded top-k MoE over h: [B, S, D] -> [B, S, D].

    SHARD-LOCAL dispatch: tokens are grouped by their data shard
    ([G, T_loc, D] with G = data-parallel width, leading dim sharded), so
    the capacity cumsum, the scatter into the [G, E, C_loc, D] expert
    buffers and the gather back are all shard-local — GSPMD emits ZERO
    collectives for dispatch/combine (measured: the global-cumsum variant
    cost 1.76 TB/device of all-reduce on mixtral train_4k).  Capacity is
    per shard (C_loc = cf * T_loc * k / E), the standard GShard practice;
    with one shard this degenerates to exact global capacity (the unit
    tests' semantics).  Overflow beyond C_loc is dropped.
    """
    from repro.sharding.rules import logical_axis_size

    b, s, d = h.shape
    t = b * s
    e, k = cfg.n_experts, cfg.moe_top_k
    g = logical_axis_size("batch")
    if t % g != 0:
        g = 1
    tl = t // g  # tokens per shard group
    cap = max(int(cfg.capacity_factor * tl * k / e), cfg.moe_top_k)
    x = h.reshape(g, tl, d)
    x = shard(x, "batch", None, "embed")

    logits = jnp.einsum(
        "gtd,de->gte", x, p["router"], preferred_element_type=F32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # [G, Tl, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    if aux is not None:
        # load-balancing auxiliary loss terms (Switch/GShard)
        me = probs.mean((0, 1))  # [E]
        ce = jax.nn.one_hot(idx[..., 0], e, dtype=F32).mean((0, 1))
        aux["moe_aux"] = aux.get("moe_aux", 0.0) + e * jnp.sum(me * ce)

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # [G, Tl, k, E]
    flat = onehot.reshape(g, tl * k, e)
    # priority order within the shard: earlier tokens win capacity slots
    pos = jnp.cumsum(flat, axis=1) - flat
    pos_sel = (pos * flat).sum(-1)  # [G, Tl*k]
    e_sel = idx.reshape(g, tl * k)
    keep = pos_sel < cap

    xrep = jnp.broadcast_to(x[:, :, None, :], (g, tl, k, d)).reshape(
        g, tl * k, d
    )
    contrib = jnp.where(keep[..., None], xrep, jnp.zeros_like(xrep))
    gidx = jnp.broadcast_to(jnp.arange(g)[:, None], (g, tl * k))
    buf = jnp.zeros((g, e, cap, d), h.dtype)
    buf = buf.at[
        gidx, jnp.where(keep, e_sel, 0), jnp.where(keep, pos_sel, 0)
    ].add(contrib, mode="drop")
    buf = shard(buf, "batch", "expert", "capacity", "embed")

    if cfg.mlp_act == "swiglu":
        g_ = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"],
                        preferred_element_type=F32)
        u_ = jnp.einsum("gecd,edf->gecf", buf, p["w_up"],
                        preferred_element_type=F32)
        down_t = h.dtype if cfg.tp_ar_bf16 else F32
        a_ = (jax.nn.silu(g_) * u_).astype(h.dtype)
        a_ = shard(a_, "batch", "expert", "capacity", "mlp")
        o_ = jnp.einsum("gecf,efd->gecd", a_, p["w_down"],
                        preferred_element_type=down_t)
    else:
        down_t = h.dtype if cfg.tp_ar_bf16 else F32
        a_ = jax.nn.gelu(
            jnp.einsum("gecd,edf->gecf", buf, p["w_in"],
                       preferred_element_type=F32)
        ).astype(h.dtype)
        a_ = shard(a_, "batch", "expert", "capacity", "mlp")
        o_ = jnp.einsum("gecf,efd->gecd", a_, p["w_out"],
                        preferred_element_type=down_t)
    o_ = shard(o_.astype(h.dtype), "batch", "expert", "capacity", "embed")

    y_slots = o_[
        gidx, jnp.where(keep, e_sel, 0), jnp.where(keep, pos_sel, 0)
    ]
    gate_flat = gate.reshape(g, tl * k)
    y_slots = jnp.where(keep[..., None], y_slots, jnp.zeros_like(y_slots))
    y_slots = (y_slots.astype(F32) * gate_flat[..., None]).astype(h.dtype)
    y = y_slots.reshape(g, tl, k, d).sum(axis=2)
    return shard(y.reshape(b, s, d), "batch", "seq", "embed")

"""The paper's technique integrated into the LM substrate.

Two first-class features, enabled per-config:

  * ``binary_ffn`` — BitLinear FFN projections: weights and activations
    binarized to +-1 (sign-STE in training), matmul on the MXU as a +-1
    GEMM with XNOR-Net scale recovery (alpha = E|W| per out-channel,
    beta = E|x| per token).  The HBM side stores/loads weights bit-packed
    (32x smaller than f32) — kernels/binary_gemm.py is the packed serving
    path; training uses the differentiable +-1 GEMM below.

  * ``cam_head`` — the PiC-BNN CAM-ensemble LM head for greedy decode:
    the vocab projection is replaced by Algorithm 1 — binarize the final
    hidden state, compute its Hamming distance to every (binarized) vocab
    row, and emit per-class VOTES over the 33-threshold sweep instead of
    full-precision logits.  argmax(votes) == argmax(dot) up to the sweep's
    step-2 quantization (ties), exactly the paper's accuracy/precision
    trade.  Practical at small vocab (musicgen, 2048 classes = one CAM
    bank config); lowered-but-capacity-flagged at 128k+ vocab (DESIGN.md
    §Arch-applicability).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.binarize import sign_ste
from repro.sharding import shard

F32 = jnp.float32


# ---------------------------------------------------------------------------
# BitLinear FFN
# ---------------------------------------------------------------------------
def _bit_matmul(x, w):
    """sign(x) @ sign(w) with XNOR-Net scale recovery, differentiable.

    x: [..., K] latent activations; w: [K, N] latent weights.
    On TPU the +-1 operands hit the int8 MXU path (serving casts to int8;
    training keeps the STE-differentiable float +-1 form).
    """
    alpha = jnp.mean(jnp.abs(w), axis=0)  # [N] per-out-channel scale
    beta = jnp.mean(jnp.abs(x), axis=-1, keepdims=True)  # [..., 1]
    xb = sign_ste(x.astype(F32))
    wb = sign_ste(w.astype(F32))
    y = jax.lax.dot_general(
        xb, wb, (((x.ndim - 1,), (0,)), ((), ())), preferred_element_type=F32
    )
    return (y * alpha * beta).astype(x.dtype)


def bitlinear_mlp(p, cfg: ModelConfig, h):
    """Drop-in binary replacement for layers.mlp (same param pytree)."""
    if cfg.mlp_act == "swiglu":
        gate = _bit_matmul(h, p["w_gate"])
        up = _bit_matmul(h, p["w_up"])
        act = shard(jax.nn.silu(gate.astype(F32)).astype(h.dtype) * up,
                    "batch", "seq", "mlp")
        return shard(_bit_matmul(act, p["w_down"]), "batch", "seq", "embed")
    act = jax.nn.gelu(_bit_matmul(h, p["w_in"]).astype(F32)).astype(h.dtype)
    act = shard(act, "batch", "seq", "mlp")
    return shard(_bit_matmul(act, p["w_out"]), "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# CAM-ensemble LM head (Algorithm 1 as the vocab projection)
# ---------------------------------------------------------------------------
def init_cam_head(cfg: ModelConfig, key):
    rows = (
        jax.random.normal(key, (cfg.vocab_size, cfg.d_model), cfg.jax_dtype)
        * cfg.d_model**-0.5
    )
    # Sweep centered on the majority point of a d_model-bit row (the
    # ensemble.build_head convention).  Beyond-paper adaptation: the
    # paper's step-2 sweep covers +-32 HD — enough to separate 10-20
    # classes, but at LM vocab scale (2048+ classes) the min-HD among
    # thousands of rows routinely falls outside a +-32 window and every
    # saturated class ties.  We scale the sweep to +-3 sigma of the
    # HD distribution (sigma = sqrt(D)/2 for random +-1 rows), keeping
    # the paper's pass count.
    n_pass = cfg.cam_head_thresholds
    center = cfg.d_model // 2
    # The sweep must bracket the BEST-matching row among V candidates.
    # Extreme-value theory: min-HD over V ~Binomial(D, 1/2) rows sits at
    # center - sigma*sqrt(2 ln V); we take one extra sigma of margin.
    # The pass count sets the resolution; tie-free ranking needs step 1,
    # i.e. n_pass >= 2*halfspan + 1 (quantified in examples/picbnn_serve
    # .py's pass-count sweep).
    import math

    sigma = (cfg.d_model**0.5) / 2.0
    halfspan = max(
        int(sigma * (math.sqrt(2.0 * math.log(max(cfg.vocab_size, 2))) + 1.0)
            + 0.5),
        1,
    )
    t = center - halfspan + jnp.round(
        jnp.linspace(0, 2 * halfspan, n_pass)
    ).astype(jnp.int32)
    return {"rows": rows, "thresholds": t}


def cam_head_axes(cfg: ModelConfig):
    return {"rows": ("p_vocab", "p_mlp_d"), "thresholds": (None,)}


def cam_head_logits(p, cfg: ModelConfig, h):
    """Greedy-decode 'logits' from the binary CAM match.

    h: [B, D] final hidden states.  The +-1 GEMM runs on the MXU (the
    TPU-native CAM search; DESIGN.md §2); HD = (D - dot) / 2.

    cfg.cam_head_mode:
      "votes" — Algorithm-1 vote counts #{t : HD <= T_t} (PiC-BNN: purely
                binary measurements, no ADC);
      "exact" — the full-precision popcount readout (the ADC/TDC baseline
                the paper compares against; same binary matching, analog
                readout precision).
    Output is float so the engine's argmax/sampling interface is unchanged.
    """
    hb = jnp.where(h >= 0, 1.0, -1.0).astype(cfg.jax_dtype)
    rb = jnp.where(p["rows"] >= 0, 1.0, -1.0).astype(cfg.jax_dtype)
    dot = jax.lax.dot_general(
        hb, rb.T, (((1,), (0,)), ((), ())), preferred_element_type=F32
    )  # [B, V]
    if cfg.cam_head_mode == "exact":
        return shard(dot, "batch", "vocab")
    hd = (cfg.d_model - dot) * 0.5
    votes = (hd[..., None] <= p["thresholds"].astype(F32)).sum(-1)
    return shard(votes.astype(F32), "batch", "vocab")

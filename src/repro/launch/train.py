"""Production training launcher.

Wires together: config registry -> mesh -> sharding rules -> data
pipeline -> jitted train step -> fault-tolerant supervisor (checkpoint /
restart / straggler monitor).  The same entry point drives the CPU smoke
presets and the full assigned architectures (the latter compile via the
dry-run; actually *executing* them needs TPUs).

Usage:
  python -m repro.launch.train --arch llama3.2-1b+smoke --steps 20
  python -m repro.launch.train --arch custom-100m --steps 300 \
      --batch 8 --seq 512 --ckpt-dir /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro import configs
from repro.configs.base import ModelConfig
from repro.data.tokens import DataConfig, synthetic_stream, embeds_stream
from repro.ft import Supervisor, SupervisorConfig
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.sharding import TRAIN_RULES, use_rules
from repro.train import TrainConfig, init_train_state
from repro.train.train_step import train_step
import functools


def custom_100m() -> ModelConfig:
    """~100M-parameter llama-style model for the end-to-end example."""
    return ModelConfig(
        name="custom-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_ff=2048,
        vocab_size=32000,
        mlp_act="swiglu",
        norm="rmsnorm",
        remat="none",
        dtype="float32",
    )


def get_cfg(name: str) -> ModelConfig:
    if name == "custom-100m":
        return custom_100m()
    return configs.get_config(name)


def make_batch_iter(cfg: ModelConfig, batch: int, seq: int, start: int):
    dcfg = DataConfig(batch=batch, seq_len=seq, vocab_size=cfg.vocab_size)
    it = (
        embeds_stream(dcfg, cfg.d_model)
        if cfg.embeds_input
        else synthetic_stream(dcfg)
    )
    # fast-forward for deterministic restart (synthetic streams are
    # seeded per-step, so skipping is O(steps) cheap host work)
    for _ in range(start):
        next(it)
    return it


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="custom-100m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_cfg(args.arch)
    mesh = make_host_mesh(args.model_parallel)
    rules = TRAIN_RULES.resolve(mesh)
    from repro.train.optimizer import OptimizerConfig

    tcfg = TrainConfig(
        opt=OptimizerConfig(lr=args.lr),
        microbatches=args.microbatches,
    )

    with use_rules(rules, mesh):
        state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
        step_fn = jax.jit(
            functools.partial(train_step, cfg, tcfg), donate_argnums=(0,)
        )

        n_params = sum(
            x.size for x in jax.tree_util.tree_leaves(state["params"])
        )
        print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
              f"devices={len(jax.devices())} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

        losses = []

        def logged_step(state, batch):
            batch = jax.tree_util.tree_map(jax.numpy.asarray, batch)
            new_state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            step = len(losses)
            if step % args.log_every == 0:
                print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            return new_state, metrics

        if args.ckpt_dir:
            sup = Supervisor(
                SupervisorConfig(
                    ckpt_dir=Path(args.ckpt_dir),
                    ckpt_every=args.ckpt_every,
                ),
                logged_step,
                lambda start: make_batch_iter(cfg, args.batch, args.seq, start),
                state_template=jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
                ),
            )
            state = sup.run(state, args.steps)
        else:
            it = make_batch_iter(cfg, args.batch, args.seq, 0)
            for _ in range(args.steps):
                state, _ = logged_step(state, next(it))

    first = np.mean(losses[: max(len(losses) // 10, 1)])
    last = np.mean(losses[-max(len(losses) // 10, 1):])
    print(f"[train] done: loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct stand-ins for every model input — the dry-run contract.

``input_specs(cfg, shape)`` returns abstract values for the *data* inputs
of the lowered step; ``state_specs`` / ``cache_specs`` produce the model
state (params, optimizer, KV cache) via jax.eval_shape — nothing is ever
allocated.  ``attach_shardings`` pins NamedShardings onto the structs so
jit infers in_shardings directly from the arguments.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.sharding import AxisRules
from repro.train import TrainConfig, optimizer as O


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract data inputs for one (arch x shape) cell.

    train  : {"tokens"|"embeds", "labels"}          (per Eq.-style LM loss)
    prefill: {"tokens"|"embeds"}
    decode : {"tokens"|"embeds" (len-1), "pos"}     (cache comes separately)
    """
    b, s = shape.global_batch, shape.seq_len
    tok = lambda ss: jax.ShapeDtypeStruct((b, ss), jnp.int32)
    emb = lambda ss: jax.ShapeDtypeStruct((b, ss, cfg.d_model), cfg.jax_dtype)
    data_in = emb if cfg.embeds_input else tok
    key = "embeds" if cfg.embeds_input else "tokens"
    if shape.kind == "train":
        return {key: data_in(s), "labels": tok(s)}
    if shape.kind == "prefill":
        return {key: data_in(s)}
    if shape.kind == "decode":
        return {key: data_in(1), "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(shape.kind)


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, rules: AxisRules):
    spec2 = rules.spec("batch", "seq")
    spec3 = rules.spec("batch", "seq", "embed")
    data = spec3 if cfg.embeds_input else spec2
    key = "embeds" if cfg.embeds_input else "tokens"
    if shape.kind == "train":
        return {key: data, "labels": spec2}
    if shape.kind == "prefill":
        return {key: data}
    return {key: data, "pos": P()}


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0))
    )


def state_specs(cfg: ModelConfig, tcfg: Optional[TrainConfig] = None):
    tcfg = tcfg or TrainConfig()
    params = params_specs(cfg)
    opt = jax.eval_shape(lambda p: O.init_opt_state(tcfg.opt, p), params)
    return {"params": params, "opt": opt}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len)
    )


def _tree_with_shardings(tree, pspec_tree, mesh: Mesh):
    from repro.sharding.rules import sanitize_spec

    def attach(sds, spec):
        spec = sanitize_spec(spec, sds.shape, mesh)
        return jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
        )

    return jax.tree_util.tree_map(
        attach, tree, pspec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def train_cell_args(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, rules: AxisRules,
    tcfg: Optional[TrainConfig] = None,
    param_rules: Optional[AxisRules] = None,
):
    """(state, batch) ShapeDtypeStructs with shardings for train_step.

    param_rules: optional separate rule set for the WORKING parameters
    (ZeRO-1: replicated bf16 params + data-sharded optimizer state)."""
    state = state_specs(cfg, tcfg)
    p_ps = M.param_pspecs(cfg, rules)
    work_ps = (
        M.param_pspecs(cfg, param_rules) if param_rules is not None else p_ps
    )
    opt_leaf_ps = {"m": p_ps, "v": p_ps, "step": P()}
    if "master" in state["opt"]:
        opt_leaf_ps["master"] = p_ps
    state_ps = {"params": work_ps, "opt": opt_leaf_ps}
    batch = input_specs(cfg, shape)
    b_ps = batch_pspecs(cfg, shape, rules)
    return (
        _tree_with_shardings(state, state_ps, mesh),
        _tree_with_shardings(batch, b_ps, mesh),
    )


def prefill_cell_args(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, rules: AxisRules
):
    params = params_specs(cfg)
    p_ps = M.param_pspecs(cfg, rules)
    batch = input_specs(cfg, shape)
    b_ps = batch_pspecs(cfg, shape, rules)
    return (
        _tree_with_shardings(params, p_ps, mesh),
        _tree_with_shardings(batch, b_ps, mesh),
    )


def decode_cell_args(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, rules: AxisRules
):
    params = params_specs(cfg)
    p_ps = M.param_pspecs(cfg, rules)
    cache = cache_specs(cfg, shape)
    c_ps = M.cache_pspecs(cfg, rules)
    batch = input_specs(cfg, shape)
    b_ps = batch_pspecs(cfg, shape, rules)
    data_key = "embeds" if cfg.embeds_input else "tokens"
    return (
        _tree_with_shardings(params, p_ps, mesh),
        _tree_with_shardings(cache, c_ps, mesh),
        _tree_with_shardings(batch[data_key], b_ps[data_key], mesh),
        _tree_with_shardings(batch["pos"], P(), mesh),
    )

"""Launchers: production mesh, multi-pod dry-run, roofline tooling,
training/serving entry points.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import — import it only as a
program entry point (``python -m repro.launch.dryrun``), never from
library code.
"""

from repro.launch.mesh import make_production_mesh, make_host_mesh  # noqa: F401

"""Serving launcher: load (or init) params, start the batched engine,
run a synthetic request workload, report throughput/latency.

Usage:
  python -m repro.launch.serve --arch musicgen-medium+smoke --requests 16
  python -m repro.launch.serve --arch llama3.2-1b+smoke --cam-head
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serve.engine import Engine, EngineConfig, Request
from repro.sharding import SERVE_RULES, use_rules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b+smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cam-head", action="store_true",
                    help="use the PiC-BNN CAM-ensemble head for decode")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    name = args.arch + ("+cam-head" if args.cam_head else "")
    cfg = configs.get_config(name)
    mesh = make_host_mesh(args.model_parallel)
    rules = SERVE_RULES.resolve(mesh)
    rng = np.random.default_rng(0)

    with use_rules(rules, mesh):
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        engine = Engine(
            cfg, params,
            EngineConfig(max_batch=args.batch, eos_id=-1),
        )
        reqs = [
            Request(
                uid=i,
                prompt=rng.integers(
                    1, cfg.vocab_size, args.prompt_len
                ).astype(np.int32),
                max_new_tokens=args.max_new,
            )
            for i in range(args.requests)
        ]
        t0 = time.time()
        results = engine.generate(reqs)
        wall = time.time() - t0

    n_tokens = sum(len(r.tokens) for r in results)
    print(f"[serve] arch={cfg.name} requests={len(results)} "
          f"new_tokens={n_tokens} wall={wall:.2f}s "
          f"({n_tokens / wall:.1f} tok/s)")
    for r in results[:3]:
        print(f"  uid={r.uid} prefill={r.prefill_ms:.1f}ms "
              f"decode={r.decode_ms:.1f}ms tokens={r.tokens[:8]}...")
    return results


if __name__ == "__main__":
    main()

"""Trip-count-aware cost extraction from optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts the body of every
``while`` loop ONCE, but jax.lax.scan lowers to a while loop — so for a
scanned-layer model the built-in numbers under-report FLOPs/bytes by a
factor of the layer count (verified empirically; see DESIGN.md §Roofline
methodology).  This walker parses ``compiled.as_text()`` and:

  * multiplies every while body by its trip count (scan-generated loop
    conditions are ``iter < constant`` — the constant is recovered from
    the condition computation);
  * resolves collective operand shapes through a per-computation symbol
    table (operands are %name references in optimized HLO);
  * counts dot/convolution FLOPs exactly (contracting dims parsed);
  * models HBM traffic at fusion granularity (a fusion's operands +
    results cross HBM; its internals live in registers/VMEM);
  * models per-device wire bytes per collective from replica-group size:
      all-gather       (n-1)/n * result
      reduce-scatter   (n-1)/n * operand
      all-reduce       2 (n-1)/n * operand   (RS + AG)
      all-to-all       (n-1)/n * operand
      collective-permute  operand

All shapes in post-SPMD HLO are PER-PARTITION, so every number reported
here is per-device, matching the roofline denominators (chip FLOP/s, chip
HBM bw, chip link bw).
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "bf16": 2,
    "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_OP_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|condition|body|branch_computations)=%?([\w.\-{}, %]+)"
)
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "conditional",
    "call", "custom-call", "get-dimension-size", "domain", "opt-barrier",
}

# VMEM-residency model: tensors at or below this size are assumed to stay
# on-chip across fusion boundaries (registers/VMEM), so ops whose largest
# operand/result is below it contribute no HBM traffic.  Without this, a
# sequential scan (mamba: 4096 steps x 64 layers) charges its few-MB carry
# tensors per trip and inflates the memory term by ~1000x; with it, the
# loop's real HBM traffic is the xs/ys arrays — charged once at the while
# op itself (its tuple operands hold the full stacked xs/ys).
VMEM_RESIDENT_BYTES = 16 * 2**20


def cost_analysis_dict(compiled) -> dict:
    """`Compiled.cost_analysis()` across jax versions.

    Newer jax returns a flat dict; older versions return a single-element
    list of dicts (one per partition). Normalize to a dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def _shape_bytes(type_str: str) -> float:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[tuple]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    if dims == "":
        return ()
    return tuple(int(d) for d in dims.split(","))


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # text after the opening paren of the operand list
    operands: list


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    symbols: dict  # %name -> type_str


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.startswith("HloModule"):
            continue
        head = _COMP_HEAD_RE.match(line)
        if head and line.rstrip().endswith("{"):
            cur = Computation(name=head.group(1), ops=[], symbols={})
            comps[cur.name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_HEAD_RE.match(line)
        if not m:
            # parameters inside the signature line etc.
            continue
        name = m.group(1)
        after = line[m.end():]
        # the result type: either a balanced-paren tuple — which may
        # contain `/*index=5*/` comment markers (an '=' inside!) — or a
        # single token
        if after.startswith("("):
            depth = 0
            end = len(after)
            for i, ch in enumerate(after):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i + 1
                        break
            type_str, after = after[:end], after[end:].lstrip()
        else:
            parts = after.split(None, 1)
            type_str = parts[0]
            after = parts[1] if len(parts) > 1 else ""
        m2 = _OPCODE_RE.match(after)
        if not m2:
            continue
        opcode, rest = m2.groups()
        # operand names: up to the closing paren of the operand list
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_text = rest[:end]
        operands = _OPERAND_RE.findall(operand_text)
        op = Op(name=name, type_str=type_str, opcode=opcode, rest=rest,
                operands=operands)
        cur.ops.append(op)
        cur.symbols[name] = type_str
    return comps


def _trip_count(cond: Computation) -> int:
    """Max integer constant in a scan-style loop condition (iter < N).

    jax.lax.scan lowers to ``while (iter < length)``; the length is the
    only large integer constant in the condition computation."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = _CONST_RE.search("constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _group_size(op: Op) -> int:
    m = _GROUPS_RE.search(op.rest)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(op.rest)
    if m:  # explicit groups {{0,1},{2,3}}
        first = m.group(1).split("}")[0].strip("{ ")
        return max(len([x for x in first.split(",") if x.strip() != ""]), 1)
    return 1


def _dot_flops(op: Op, comp: Computation) -> float:
    out_dims = _shape_dims(op.type_str) or ()
    out_elems = math.prod(out_dims) if out_dims else 1
    # contracting dims of the lhs operand
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    if m and op.operands:
        lhs_type = comp.symbols.get(op.operands[0])
        lhs_dims = _shape_dims(lhs_type) if lhs_type else None
        if lhs_dims and m.group(1):
            for d in m.group(1).split(","):
                di = int(d)
                if di < len(lhs_dims):
                    contract *= lhs_dims[di]
    return 2.0 * out_elems * contract


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_operand_bytes: float = 0.0
    by_collective: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    collective_count: int = 0
    # HBM attribution: "opcode@op_name-prefix" -> bytes (trip-multiplied)
    hbm_by_op: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_wire_bytes += other.collective_wire_bytes * mult
        self.collective_operand_bytes += (
            other.collective_operand_bytes * mult
        )
        for k, v in other.by_collective.items():
            self.by_collective[k] += v * mult
        self.collective_count += int(other.collective_count * mult)
        for k, v in other.hbm_by_op.items():
            self.hbm_by_op[k] += v * mult

    def top_hbm(self, n: int = 12) -> list:
        return sorted(self.hbm_by_op.items(), key=lambda kv: -kv[1])[:n]


def _walk(
    comps: dict[str, Computation],
    comp: Computation,
    memo: dict,
) -> CostTotals:
    """Cost of one execution of `comp` (recursively, trip-count aware)."""
    if comp.name in memo:
        return memo[comp.name]
    t = CostTotals()
    for op in comp.ops:
        oc = op.opcode
        base = oc.replace("-start", "")
        if base in COLLECTIVES:
            n = _group_size(op)
            opnd_bytes = sum(
                _shape_bytes(comp.symbols.get(o, "")) for o in op.operands
            )
            out_bytes = _shape_bytes(op.type_str)
            frac = (n - 1) / n if n > 1 else 0.0
            if base == "all-gather":
                wire = out_bytes * frac
            elif base == "reduce-scatter":
                wire = opnd_bytes * frac
            elif base == "all-reduce":
                wire = 2.0 * opnd_bytes * frac
            elif base in ("all-to-all", "ragged-all-to-all"):
                wire = opnd_bytes * frac
            else:  # collective-permute / broadcast
                wire = opnd_bytes
            t.collective_wire_bytes += wire
            t.collective_operand_bytes += opnd_bytes
            t.by_collective[base] += wire
            t.collective_count += 1
            t.hbm_bytes += opnd_bytes + out_bytes
            t.hbm_by_op[_op_key(op)] += opnd_bytes + out_bytes
            continue
        if oc == "while":
            # the loop tuple holds the stacked xs/ys (+ carries): charge it
            # once — the scan's end-to-end HBM traffic
            opnd_bytes = sum(
                _shape_bytes(comp.symbols.get(o, "")) for o in op.operands
            )
            t.hbm_bytes += opnd_bytes + _shape_bytes(op.type_str)
            t.hbm_by_op["while-tuple@" + op.name] += (
                opnd_bytes + _shape_bytes(op.type_str)
            )
            body_name = re.search(r"body=%?([\w.\-]+)", op.rest)
            cond_name = re.search(r"condition=%?([\w.\-]+)", op.rest)
            # primary source: XLA's own analysis in backend_config
            tm = _TRIP_RE.search(op.rest)
            if tm:
                trips = int(tm.group(1))
            elif cond_name and cond_name.group(1) in comps:
                trips = _trip_count(comps[cond_name.group(1)])
            else:
                trips = 1
            if body_name and body_name.group(1) in comps:
                t.add(_walk(comps, comps[body_name.group(1)], memo), trips)
            continue
        if oc == "conditional":
            for name in re.findall(r"%([\w.\-]+)", op.rest):
                if name in comps:
                    t.add(_walk(comps, comps[name], memo), 1.0)
            continue
        if oc in ("call", "async-start"):
            m = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", op.rest)
            if m and m.group(1) in comps:
                t.add(_walk(comps, comps[m.group(1)], memo), 1.0)
            continue
        if oc == "fusion":
            # FLOPs: descend into the fused computation (dots can hide
            # there); bytes: fusion boundary = HBM materialization, under
            # the VMEM-residency model.
            m = re.search(r"calls=%?([\w.\-]+)", op.rest)
            if m and m.group(1) in comps:
                inner = _walk(comps, comps[m.group(1)], memo)
                t.flops += inner.flops
            hb = _op_hbm_bytes(op, comp, comps)
            t.hbm_bytes += hb
            if hb:
                t.hbm_by_op[_op_key(op)] += hb
            continue
        if oc in ("dot", "convolution"):
            t.flops += _dot_flops(op, comp)
            hb = _op_hbm_bytes(op, comp, comps)
            t.hbm_bytes += hb
            if hb:
                t.hbm_by_op[_op_key(op)] += hb
            continue
        if oc in _SKIP_BYTES_OPS:
            continue
        # generic compute op (copy, reduce, broadcast, iota, slice, ...)
        hb = _op_hbm_bytes(op, comp, comps)
        t.hbm_bytes += hb
        if hb:
            t.hbm_by_op[_op_key(op)] += hb
        # elementwise flops ~ one per output element (minor vs dots)
        out = _shape_dims(op.type_str)
        if out:
            t.flops += math.prod(out)
    memo[comp.name] = t
    return t


def _sliced_params(comp: Computation) -> dict:
    """Fusion-computation parameters consumed ONLY via dynamic-slice:
    param index -> slice result bytes.  A fusion that dynamic-slices a
    big stacked array (scan xs) reads one SLICE per execution, not the
    whole operand — without this, every scan-body fusion gets charged
    the full stacked array per trip (measured 89 TB of phantom traffic
    on the mamba cell)."""
    # parameter op name -> index (op.rest = "<idx>), ..." after "parameter(")
    param_idx: dict[str, int] = {}
    for op in comp.ops:
        if op.opcode == "parameter":
            mi = re.match(r"(\d+)\)", op.rest)
            if mi:
                param_idx[op.name] = int(mi.group(1))
    uses: dict[str, list] = {name: [] for name in param_idx}
    for op in comp.ops:
        for o in op.operands:
            if o in uses:
                uses[o].append(op)
    out: dict[int, float] = {}
    for pname, ops in uses.items():
        if ops and all(o.opcode == "dynamic-slice" for o in ops):
            out[param_idx[pname]] = max(
                _shape_bytes(o.type_str) for o in ops
            )
    return out


def _op_key(op: Op) -> str:
    m = re.search(r'op_name="([^"]+)"', op.rest)
    tag = m.group(1).split("/")[-1][:48] if m else op.name[:32]
    return f"{op.opcode}@{tag}"


def _op_hbm_bytes(
    op: Op, comp: Computation, comps: Optional[dict] = None
) -> float:
    """Operand+result bytes, zero when everything fits in VMEM.

    dynamic-update-slice (and fusions rooted in one) ALIAS the big buffer
    operand in place: the real traffic is the update slice written (plus
    its read), not the whole buffer — without this, a scan stacking its
    per-step outputs (ys) gets charged the full stacked array per step
    (measured 400+ TB phantom traffic on the mamba train cell).
    Similarly, fusion operands consumed only through dynamic-slice inside
    the fused computation are charged at SLICE size (scan xs reads)."""
    opnd = [_shape_bytes(comp.symbols.get(o, "")) for o in op.operands]
    res = _shape_bytes(op.type_str)
    if max(opnd + [res], default=0.0) <= VMEM_RESIDENT_BYTES:
        return 0.0
    if op.opcode == "dynamic-update-slice" or (
        op.opcode == "fusion"
        and ("dynamic_update_slice" in op.rest
             or "dynamic-update-slice" in op.rest)
    ):
        # in-place: charge everything except the aliased buffer (the
        # largest operand) and the aliased result
        big = max(opnd, default=0.0)
        return max(sum(opnd) - big, 0.0) * 2.0
    if op.opcode == "fusion" and comps is not None:
        m = re.search(r"calls=%?([\w.\-]+)", op.rest)
        if m and m.group(1) in comps:
            sliced = _sliced_params(comps[m.group(1)])
            if sliced:
                adj = list(opnd)
                for i, sz in sliced.items():
                    if i < len(adj):
                        adj[i] = min(adj[i], sz)
                if max(adj + [res], default=0.0) <= VMEM_RESIDENT_BYTES:
                    return 0.0
                return sum(adj) + res
    return sum(opnd) + res


# computations reachable only as fusion internals shouldn't be re-walked
def analyze_hlo_text(text: str) -> CostTotals:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEAD_RE.match(line.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None or entry not in comps:
        # fall back: the computation named main-ish
        for name in comps:
            if "main" in name:
                entry = name
                break
    if entry is None:
        return CostTotals()
    memo: dict = {}
    totals = CostTotals()
    totals.add(_walk(comps, comps[entry], memo), 1.0)
    totals.by_collective = dict(totals.by_collective)
    totals.hbm_by_op = dict(totals.hbm_by_op)
    return totals

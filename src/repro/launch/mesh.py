"""Production mesh construction.

Target hardware: TPU v5e pods of 256 chips (16x16 ICI torus); multi-pod
runs add a leading DCN 'pod' axis.  Never touches jax device state at
import time — meshes are built on demand inside launchers.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single-pod mesh, or 2x16x16 (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / reduced dry-runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def validate_mesh(mesh) -> dict:
    """Shape/axis report used by the dry-run logs."""
    return {
        "axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": int(np.prod(mesh.devices.shape)),
        "platform": mesh.devices.flatten()[0].platform,
    }

"""Roofline term derivation for TPU v5e from compiled dry-run artifacts.

Hardware constants (per chip):
    197 TFLOP/s bf16  |  819 GB/s HBM  |  ~50 GB/s per ICI link

Three terms, all in seconds-per-step (lower bounds assuming perfect
overlap within each resource):
    compute    = device_flops / 197e12
    memory     = device_hbm_bytes / 819e9
    collective = device_wire_bytes / 50e9

device_* numbers come from the trip-count-aware HLO walker
(launch/hlo_cost.py) — post-SPMD shapes are per-partition, so the walker
output is already per-device.  The built-in ``cost_analysis()`` numbers
are recorded alongside for reference, with the documented while-loop
caveat (scan bodies counted once).

MODEL_FLOPS is the analytic useful-work count (6*N*D for training dense,
6*N_active*D for MoE, plus attention terms); the ratio
MODEL_FLOPS / HLO_FLOPS exposes remat recompute and sharding redundancy.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Analytic useful FLOPs per step (global, fwd [+bwd for train])."""
    n_active = cfg.active_param_count()
    n_embed = cfg.vocab_size * cfg.d_model * (2 if not cfg.tie_embeddings else 1)
    # matmul params exclude embedding lookup (gather, ~0 flops) but the
    # 6ND convention includes the lm_head matmul == vocab*d once
    n_matmul = n_active - n_embed + cfg.vocab_size * cfg.d_model

    pat = cfg.pattern()
    attn_subs = [i for i, k in enumerate(pat.kinds) if k == "attn"]

    b = shape.global_batch
    if shape.kind == "decode":
        tokens = b  # one token per sequence
        # attention reads the whole cache (or window) once per layer
        flops_attn = 0.0
        for i in attn_subs:
            w = pat.windows[i]
            kv = shape.seq_len if w is None else min(w, shape.seq_len)
            flops_attn += cfg.blocks * 4.0 * b * kv * cfg.n_heads * cfg.head_dim
        fwd = 2.0 * n_matmul * tokens + flops_attn
        return {"total": fwd, "matmul": 2.0 * n_matmul * tokens,
                "attention": flops_attn, "tokens": tokens}

    s = shape.seq_len
    tokens = b * s
    flops_attn = 0.0
    for i in attn_subs:
        w = pat.windows[i]
        kv_avg = s / 2 if w is None else min(w, s / 2)
        flops_attn += cfg.blocks * 4.0 * b * s * kv_avg * cfg.n_heads * cfg.head_dim
    fwd = 2.0 * n_matmul * tokens + flops_attn
    if shape.kind == "train":
        total = 3.0 * fwd  # bwd ~ 2x fwd
    else:
        total = fwd
    return {"total": total, "matmul": (3.0 if shape.kind == "train" else 1.0)
            * 2.0 * n_matmul * tokens,
            "attention": (3.0 if shape.kind == "train" else 1.0) * flops_attn,
            "tokens": tokens}


@dataclasses.dataclass
class RooflineReport:
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_global: float
    hlo_flops_global: float
    useful_ratio: float
    step_time_lb_s: float
    roofline_fraction: float  # useful-compute time / bottleneck time

    def to_dict(self):
        return dataclasses.asdict(self)


def derive(
    cfg: ModelConfig,
    shape: ShapeConfig,
    n_chips: int,
    device_flops: float,
    device_hbm_bytes: float,
    device_wire_bytes: float,
) -> RooflineReport:
    compute_s = device_flops / PEAK_FLOPS
    memory_s = device_hbm_bytes / HBM_BW
    collective_s = device_wire_bytes / ICI_BW
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)["total"]
    hlo_global = device_flops * n_chips
    useful = mf / hlo_global if hlo_global else 0.0
    step_lb = max(terms.values())
    # fraction of the machine's peak that useful work would achieve if the
    # step ran at the bottleneck bound:
    ideal_compute_s = mf / (n_chips * PEAK_FLOPS)
    frac = ideal_compute_s / step_lb if step_lb > 0 else 0.0
    return RooflineReport(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops_global=mf,
        hlo_flops_global=hlo_global,
        useful_ratio=useful,
        step_time_lb_s=step_lb,
        roofline_fraction=min(frac, 1.0),
    )

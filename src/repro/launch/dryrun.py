import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every jax-touching import: jax locks the device count at
# first backend initialization.  512 host devices back the production
# meshes (16x16 single-pod, 2x16x16 multi-pod).  This is the ONLY entry
# point that forces a device count — tests/benchmarks see the real host.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape x mesh) cell this driver:
  1. builds the production mesh (launch/mesh.py),
  2. constructs ShapeDtypeStruct stand-ins with NamedShardings attached
     (launch/specs.py) — no allocation anywhere,
  3. jit-lowers the step (train_step / prefill_step / decode_step),
  4. compiles — sharding mismatches, unsupported collectives and
     compile-time OOMs surface HERE, as hard failures,
  5. prints memory_analysis() (bytes/device: proves the config fits or
     doesn't) and cost_analysis(),
  6. runs the trip-count-aware HLO walker (launch/hlo_cost.py) and the
     roofline derivation (launch/roofline.py),
  7. writes results/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
  python -m repro.launch.dryrun --list
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro import configs
from repro.configs.base import SHAPES, applicable_shapes
from repro.launch import hlo_cost, roofline, specs
from repro.launch.mesh import make_production_mesh, validate_mesh
from repro.sharding import (
    LONG_CONTEXT_RULES,
    SERVE_RULES,
    SERVE_SEQCACHE_RULES,
    TRAIN_RULES,
    TRAIN_SP_RULES,
    ZERO1_PARAM_RULES,
    use_rules,
)
from repro.serve.steps import decode_step, prefill_step
from repro.train import TrainConfig
from repro.train.train_step import train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def rules_for(shape, variant: str = "baseline"):
    if shape.kind == "train":
        return TRAIN_SP_RULES if "sp" in variant.split("-") else TRAIN_RULES
    if shape.name == "long_500k":
        return LONG_CONTEXT_RULES
    if "seqcache" in variant.split("-"):
        return SERVE_SEQCACHE_RULES
    return SERVE_RULES


def auto_microbatches(cfg, shape, mesh, target_gib: float = 12.0) -> int:
    """Gradient-accumulation factor targeting ~target_gib of per-device
    residual carries (the block-scan saves h [B/mb/dp, S, D] per block —
    the dominant training activation term under full remat).

    This is exactly the knob a production framework config would set; the
    chosen value is recorded in the cell's JSON so the baseline is
    reproducible."""
    if shape.kind != "train":
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    if shape.global_batch % dp:
        return 1
    per_dev_batch = shape.global_batch // dp
    carries = cfg.blocks * shape.seq_len * cfg.d_model * 2 * per_dev_batch
    mb = 1
    while carries / mb > target_gib * 2**30 and mb < per_dev_batch:
        mb *= 2
    return min(mb, per_dev_batch)


def cell_id(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}__{shape}__{'multipod' if multi_pod else 'pod'}"


def lower_cell(cfg, shape, mesh, *, tcfg=None, donate=True,
               variant: str = "baseline", microbatches=None, remat=None):
    """Lower + compile one cell; returns (lowered, compiled).

    variant: '-'-separated levers: sp (sequence-parallel carries),
    zero1 (replicated params + data-sharded optimizer), seqcache
    (sequence-sharded decode cache); remat/microbatches override config.
    """
    import dataclasses as _dc
    import functools

    if remat is not None:
        cfg = _dc.replace(cfg, remat=remat)
    rules = rules_for(shape, variant).resolve(mesh)
    param_rules = (
        ZERO1_PARAM_RULES.resolve(mesh)
        if "zero1" in variant.split("-") else None
    )

    with use_rules(rules, mesh):
        if shape.kind == "train":
            mb = microbatches or auto_microbatches(cfg, shape, mesh)
            tcfg = tcfg or TrainConfig(microbatches=mb)
            state, batch = specs.train_cell_args(
                cfg, shape, mesh, rules, tcfg, param_rules=param_rules
            )
            fn = functools.partial(train_step, cfg, tcfg)
            jitted = jax.jit(fn, donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state, batch)
        elif shape.kind == "prefill":
            params, batch = specs.prefill_cell_args(cfg, shape, mesh, rules)
            fn = functools.partial(prefill_step, cfg)
            lowered = jax.jit(fn).lower(params, batch)
        else:  # decode
            params, cache, tokens, pos = specs.decode_cell_args(
                cfg, shape, mesh, rules
            )
            fn = functools.partial(decode_step, cfg)
            jitted = jax.jit(fn, donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params, cache, tokens, pos)
    compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             save_hlo: bool = False, variant: str = "baseline",
             microbatches=None, remat=None, tag: str = "") -> dict:
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = len(mesh.devices.flatten())
    t0 = time.time()
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": validate_mesh(mesh),
        "multi_pod": multi_pod,
        "variant": variant,
        "status": "running",
    }
    try:
        if shape.kind == "train":
            record["microbatches"] = (
                microbatches or auto_microbatches(cfg, shape, mesh)
            )
            record["remat"] = remat or cfg.remat
        lowered, compiled = lower_cell(
            cfg, shape, mesh, variant=variant,
            microbatches=microbatches, remat=remat,
        )
        ma = compiled.memory_analysis()
        ca = hlo_cost.cost_analysis_dict(compiled)
        hlo_text = compiled.as_text()
        walker = hlo_cost.analyze_hlo_text(hlo_text)
        rep = roofline.derive(
            cfg, shape, n_chips,
            device_flops=walker.flops,
            device_hbm_bytes=walker.hbm_bytes,
            device_wire_bytes=walker.collective_wire_bytes,
        )
        record.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            memory_analysis={
                "argument_bytes_per_device": ma.argument_size_in_bytes,
                "output_bytes_per_device": ma.output_size_in_bytes,
                "temp_bytes_per_device": ma.temp_size_in_bytes,
                "alias_bytes_per_device": ma.alias_size_in_bytes,
                "peak_estimate_gib": round(
                    (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
                    / 2**30, 3),
            },
            cost_analysis_raw={
                "flops": ca.get("flops"),
                "bytes_accessed": ca.get("bytes accessed"),
                "note": "scan bodies counted once (see hlo_walker fields)",
            },
            hlo_walker={
                "device_flops": walker.flops,
                "device_hbm_bytes": walker.hbm_bytes,
                "device_wire_bytes": walker.collective_wire_bytes,
                "device_collective_operand_bytes":
                    walker.collective_operand_bytes,
                "by_collective": walker.by_collective,
                "collective_count": walker.collective_count,
                "top_hbm": walker.top_hbm(12),
            },
            roofline=rep.to_dict(),
            hlo_size_bytes=len(hlo_text),
        )
        if save_hlo:
            (out_dir / (cell_id(arch, shape_name, multi_pod) + tag
                        + ".hlo.txt")).write_text(hlo_text)
        print(f"[dryrun] {arch} x {shape_name} x "
              f"{'multipod' if multi_pod else 'pod'}: OK "
              f"({record['compile_s']}s compile, "
              f"peak {record['memory_analysis']['peak_estimate_gib']} GiB/dev,"
              f" bottleneck={rep.bottleneck})")
        print("  memory_analysis:", record["memory_analysis"])
        print("  cost_analysis:", record["cost_analysis_raw"])
    except Exception as e:  # noqa: BLE001 — each cell must fail in isolation
        record.update(
            status="error",
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:],
            compile_s=round(time.time() - t0, 1),
        )
        print(f"[dryrun] {arch} x {shape_name}: FAILED — {e}")
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / (cell_id(arch, shape_name, multi_pod) + tag + ".json")
    out_path.write_text(json.dumps(record, indent=2, default=str))
    return record


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="train_4k|prefill_32k|decode_32k|long_500k|all")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (pod,data,model) mesh instead of 16x16")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod for each cell")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    help="'-'-joined levers: sp, zero1, seqcache")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default=None, choices=[None, "full", "dots",
                                                      "none"])
    ap.add_argument("--tag", default="",
                    help="suffix for the output json (perf experiments)")
    args = ap.parse_args()

    archs = configs.list_archs() if args.arch == "all" else [args.arch]
    out_dir = Path(args.out)

    if args.list:
        for a in archs:
            cfg = configs.get_config(a)
            names = [s.name for s in applicable_shapes(cfg)]
            skipped = [s for s in SHAPES if s not in names]
            print(f"{a}: {names}  (skipped: {skipped or 'none'})")
        return

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_ok = n_err = n_skip = 0
    for arch in archs:
        cfg = configs.get_config(arch)
        app = {s.name for s in applicable_shapes(cfg)}
        shape_names = (
            list(SHAPES) if args.shape == "all" else [args.shape]
        )
        for sn in shape_names:
            if sn not in app:
                print(f"[dryrun] {arch} x {sn}: SKIPPED "
                      f"(long-context inapplicable: full attention)")
                out_dir.mkdir(parents=True, exist_ok=True)
                for mp in meshes:
                    (out_dir / (cell_id(arch, sn, mp) + ".json")).write_text(
                        json.dumps({
                            "arch": arch, "shape": sn, "multi_pod": mp,
                            "status": "skipped",
                            "reason": "pure full-attention arch at 512k "
                                      "context (assignment exemption)",
                        }, indent=2))
                n_skip += 1
                continue
            for mp in meshes:
                if args.skip_existing:
                    p = out_dir / (cell_id(arch, sn, mp) + ".json")
                    if p.exists():
                        st = json.loads(p.read_text()).get("status")
                        if st == "ok":
                            n_skip += 1
                            continue
                rec = run_cell(arch, sn, mp, out_dir,
                               save_hlo=args.save_hlo,
                               variant=args.variant,
                               microbatches=args.microbatches,
                               remat=args.remat,
                               tag=args.tag)
                if rec["status"] == "ok":
                    n_ok += 1
                else:
                    n_err += 1
    print(f"[dryrun] done: {n_ok} ok, {n_err} failed, {n_skip} skipped")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

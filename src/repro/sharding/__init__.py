"""Logical-axis based sharding: models annotate tensors with *logical*
axis names; a rule set maps those to physical mesh axes per run mode."""

from repro.sharding.rules import (  # noqa: F401
    AxisRules,
    TRAIN_RULES,
    TRAIN_SP_RULES,
    ZERO1_PARAM_RULES,
    SERVE_RULES,
    SERVE_SEQCACHE_RULES,
    LONG_CONTEXT_RULES,
    current_rules,
    logical_to_spec,
    sanitize_spec,
    shard,
    use_rules,
)

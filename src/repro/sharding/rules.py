"""Logical-axis -> physical-mesh-axis rules (the GSPMD contract).

Models never name physical mesh axes; they annotate tensors with logical
axes ("batch", "embed", "heads", "mlp", "expert", "vocab", "kv_seq", ...).
A rule set maps logical names to physical mesh axes (or None = replicate).
This keeps one model definition valid across every parallelism layout:
swap the rules, not the model.

Physical mesh axes (launch/mesh.py):
  pod    — slowest (DCN) axis across pods; data-parallel only
  data   — intra-pod axis used for DP + FSDP (+ sequence sharding in
           long-context serving)
  model  — intra-pod tensor-parallel axis (heads / mlp / vocab / experts)

Baseline rule sets:
  TRAIN_RULES        — DP+FSDP over ('pod','data'), Megatron TP over 'model'
  SERVE_RULES        — batch over ('pod','data'), TP over 'model'
  LONG_CONTEXT_RULES — batch=1: KV sequence sharded over 'data' (sequence
                       parallelism for the half-meg context), TP otherwise
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, None, tuple]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping from logical axis names to physical mesh axes."""

    name: str
    rules: dict[str, Axis]

    def resolve(self, mesh: Mesh) -> "AxisRules":
        """Drop physical axes that don't exist in `mesh` (e.g. 'pod' on a
        single-pod mesh) so one rule set serves both mesh shapes."""
        names = set(mesh.axis_names)

        def filt(ax: Axis) -> Axis:
            if ax is None:
                return None
            if isinstance(ax, tuple):
                keep = tuple(a for a in ax if a in names)
                return keep if keep else None
            return ax if ax in names else None

        return AxisRules(
            name=f"{self.name}@{'x'.join(map(str, mesh.devices.shape))}",
            rules={k: filt(v) for k, v in self.rules.items()},
        )

    def physical(self, logical: Optional[str]) -> Axis:
        if logical is None:
            return None
        return self.rules.get(logical, None)

    def spec(self, *logical_axes: Optional[str]) -> P:
        phys = []
        used: set[str] = set()
        for ax in logical_axes:
            p = self.physical(ax)
            # one physical axis may appear at most once per spec; later
            # logical axes that map to an already-used physical axis
            # degrade to replication (GSPMD requirement)
            if p is None:
                phys.append(None)
            elif isinstance(p, tuple):
                keep = tuple(a for a in p if a not in used)
                used.update(keep)
                phys.append(keep if keep else None)
            else:
                if p in used:
                    phys.append(None)
                else:
                    used.add(p)
                    phys.append(p)
        return P(*phys)


# ---------------------------------------------------------------------------
# Baseline rule sets
# ---------------------------------------------------------------------------
TRAIN_RULES = AxisRules(
    name="train",
    rules={
        # activations
        "batch": ("pod", "data"),
        "seq": None,
        "act_seq": None,  # residual-carry sequence dim (SP variant)
        "kv_seq": None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        # second sharding dim of the [E, C, D] dispatch buffers: when the
        # expert count doesn't divide the model axis (mixtral: 8 experts
        # vs 16-way TP) the expert dim degrades to replication and the
        # capacity dim carries the sharding instead
        "capacity": "data",
        # parameters: TP on one dim, FSDP ('data') on another
        "p_embed_v": "model",  # embedding table rows (vocab)
        "p_embed_d": "data",  # embedding table cols (FSDP)
        "p_attn_d": "data",  # attention proj d_model dim (FSDP)
        "p_attn_heads": "model",  # attention heads dim (TP)
        "p_mlp_d": "data",  # mlp d_model dim (FSDP)
        "p_mlp_f": "model",  # mlp hidden dim (TP)
        "p_expert": None,  # expert dim of MoE weight stacks
        "p_vocab": "model",  # lm head vocab dim (TP)
        "p_ssm_inner": "model",  # mamba d_inner dim (TP)
        "p_ssm_d": "data",  # mamba d_model dim (FSDP)
    },
)

SERVE_RULES = AxisRules(
    name="serve",
    rules={
        "batch": ("pod", "data"),
        "seq": None,
        "kv_seq": None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "capacity": "data",  # see TRAIN_RULES note
        # serving uses 2D weight sharding: TP on 'model' plus a second
        # shard over 'data' (weight-gathered serving).  At the assigned
        # batch sizes (32-128) serving is throughput-bound, so the
        # per-layer all-gather amortizes over the batch; without it the
        # 400B-class archs cannot fit a single pod's HBM (llama3-405b
        # bf16 = 810 GB vs 16 GB/chip x 16-way TP = 50 GB/chip).
        "p_embed_v": "model",
        "p_embed_d": "data",
        "p_attn_d": "data",
        "p_attn_heads": "model",
        "p_mlp_d": "data",
        "p_mlp_f": "model",
        "p_expert": "data",  # expert-parallel over the batch axis
        "p_vocab": "model",
        "p_ssm_inner": "model",
        "p_ssm_d": "data",
    },
)

LONG_CONTEXT_RULES = AxisRules(
    name="long_context",
    rules={
        **SERVE_RULES.rules,
        # batch == 1: spend the 'data' axis on the KV sequence instead
        "batch": "pod",
        "kv_seq": "data",
    },
)

# ---------------------------------------------------------------------------
# Hillclimb variants (§Perf) — same model code, different rules
# ---------------------------------------------------------------------------

# Megatron-style sequence parallelism: the residual carries between scanned
# blocks are sharded over 'model' along the sequence; GSPMD inserts the
# all-gather at attention/MLP entry and the reduce-scatter at exit.  Cuts
# the dominant training-memory term (L x B x S x D carries) by the TP width.
TRAIN_SP_RULES = AxisRules(
    name="train_sp",
    rules={**TRAIN_RULES.rules, "act_seq": "model"},
)

# ZeRO-1: optimizer state sharded over 'data' (as in TRAIN_RULES) but the
# bf16 working parameters REPLICATED across 'data' — removes the per-
# microbatch FSDP all-gathers; gradients all-reduce once, the post-update
# parameter all-gather happens once per step.  Wins when grad-accumulation
# would otherwise repeat the weight gathers (collective-bound train cells).
ZERO1_PARAM_RULES = AxisRules(
    name="zero1_params",
    rules={
        **TRAIN_RULES.rules,
        "p_embed_d": None,
        "p_attn_d": None,
        "p_mlp_d": None,
        "p_ssm_d": None,
    },
)

# Sequence-sharded decode cache: for MHA archs whose kv-head count doesn't
# divide the TP axis (musicgen: 24 kv heads vs 16), the head-sharded cache
# degrades to replication; sharding the cache SEQUENCE over 'model' instead
# restores the 16x memory split at the cost of a small per-step all-reduce.
SERVE_SEQCACHE_RULES = AxisRules(
    name="serve_seqcache",
    rules={**SERVE_RULES.rules, "kv_seq": "model"},
)

# PiC-BNN classification serving (serve/picbnn.py, fanout="spmd"): pure
# data parallelism over one local 'data' axis — the micro-batch splits
# across devices, everything else (packed weights, folded constants,
# thresholds — all jit-closure constants of the compiled pipeline)
# replicates.  The round-robin fan-out needs no rules at all: each batch
# runs whole on one device.
PICBNN_SERVE_RULES = AxisRules(
    name="picbnn_serve",
    rules={"batch": "data", "features": None, "classes": None},
)


def serve_mesh(devices) -> Mesh:
    """A 1-axis ('data') mesh over the serving devices (local fan-out)."""
    import numpy as np

    return Mesh(np.asarray(list(devices)), ("data",))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully-replicated placement (every device holds the full array) —
    the serve-time contract for the folded weights."""
    return NamedSharding(mesh, P())


def batch_sharding(mesh: Mesh,
                   rules: AxisRules = PICBNN_SERVE_RULES) -> NamedSharding:
    """Leading-axis data-parallel placement for a served micro-batch
    (trailing dims replicated), derived through the logical rules."""
    return NamedSharding(mesh, rules.spec("batch"))


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop partitioned dims that don't divide evenly.

    GSPMD requires input dims to be divisible by their tiling factor.
    Indivisible dims degrade to replication — the standard fallback
    (e.g. Megatron replicates KV heads when tp > n_kv_heads).  Cases
    where this costs real compute (q-heads % 16 != 0: llama4's 40,
    musicgen's 24) are called out in EXPERIMENTS.md §Perf as hillclimb
    targets (head padding / mesh refactor)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        factor = 1
        for a in axes:
            factor *= sizes.get(a, 1)
        out.append(ax if factor and dim % factor == 0 else None)
    return P(*out)


class _Ctx(threading.local):
    def __init__(self):
        self.rules: Optional[AxisRules] = None
        self.mesh: Optional[Mesh] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_rules(rules: AxisRules, mesh: Optional[Mesh] = None):
    """Activate a rule set (and optionally a mesh) for model tracing."""
    prev = (_CTX.rules, _CTX.mesh)
    _CTX.rules, _CTX.mesh = rules, mesh
    try:
        yield
    finally:
        _CTX.rules, _CTX.mesh = prev


def current_rules() -> Optional[AxisRules]:
    return _CTX.rules


def logical_axis_size(logical: str) -> int:
    """Product of mesh-axis sizes a logical axis maps to (1 outside a
    rules+mesh context) — used for shard-local algorithm layouts (e.g.
    the MoE dispatch groups tokens by data shard)."""
    rules, mesh = _CTX.rules, _CTX.mesh
    if rules is None or mesh is None:
        return 1
    ax = rules.physical(logical)
    if ax is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


def logical_to_spec(*logical_axes: Optional[str]) -> P:
    rules = _CTX.rules
    if rules is None:
        return P(*([None] * len(logical_axes)))
    return rules.spec(*logical_axes)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op outside rules
    context or when no mesh is active). Indivisible dims degrade to
    replication via sanitize_spec."""
    rules = _CTX.rules
    if rules is None:
        return x
    spec = rules.spec(*logical_axes)
    mesh = _CTX.mesh
    if mesh is not None:
        spec = sanitize_spec(spec, x.shape, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    # inside jit with an ambient mesh (jax.sharding.use_mesh) this form works
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x

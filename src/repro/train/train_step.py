"""The jitted training step: loss -> grads -> (compressed) all-reduce ->
AdamW update.  One definition serves real training, the smoke tests, and
the multi-pod dry-run (lowered with ShapeDtypeStructs).

Microbatching: the global batch can be split into `microbatches` grad-
accumulation steps (a lax.scan over microbatch slices) — activation
memory scales with the microbatch, gradients accumulate in f32.

Gradient compression (train/grad_compress.py): optional 1-bit EF-signSGD
on the cross-pod (DCN) gradient reduction — thematically the paper's
binarization applied to gradients; 32x less DCN traffic at <1% quality
cost on the scales tested (see tests/test_grad_compress.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.train import optimizer as O
from repro.train.grad_compress import CompressionConfig, maybe_compress_grads


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: O.OptimizerConfig = O.OptimizerConfig()
    microbatches: int = 1
    moe_aux_weight: float = 0.01
    compression: CompressionConfig = CompressionConfig()


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key):
    params = M.init_params(cfg, key)
    return {"params": params, "opt": O.init_opt_state(tcfg.opt, params)}


def _split_microbatches(batch: dict, n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by {n} microbatches"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree_util.tree_map(split, batch)


def loss_and_grads(cfg: ModelConfig, tcfg: TrainConfig, params, batch):
    """Gradient accumulation over microbatches (scan) or a single pass."""
    lfn = lambda p, b: M.loss_fn(p, cfg, b, aux_weight=tcfg.moe_aux_weight)
    grad_fn = jax.value_and_grad(lfn, has_aux=True)
    if tcfg.microbatches <= 1:
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, grads, metrics
    mb = _split_microbatches(batch, tcfg.microbatches)
    g0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )

    def body(carry, mbatch):
        loss_sum, g_acc = carry
        (loss, metrics), grads = grad_fn(params, mbatch)
        g_acc = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32), g_acc, grads
        )
        return (loss_sum + loss, g_acc), metrics

    (loss_sum, g_acc), metrics = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), g0), mb
    )
    inv = 1.0 / tcfg.microbatches
    grads = jax.tree_util.tree_map(lambda g: g * inv, g_acc)
    metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
    return loss_sum * inv, grads, metrics


def train_step(cfg: ModelConfig, tcfg: TrainConfig, state, batch):
    """state: {"params", "opt"}; batch: {"tokens"/"embeds", "labels"}."""
    params = state["params"]
    loss, grads, metrics = loss_and_grads(cfg, tcfg, params, batch)
    grads, comp_metrics = maybe_compress_grads(tcfg.compression, grads)
    new_params, new_opt, opt_metrics = O.apply_updates(
        tcfg.opt, params, grads, state["opt"]
    )
    metrics = {"loss": loss, **metrics, **opt_metrics, **comp_metrics}
    return {"params": new_params, "opt": new_opt}, metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, donate: bool = True):
    fn = functools.partial(train_step, cfg, tcfg)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())

"""AdamW (decoupled weight decay) with mixed-precision master weights —
pure JAX, pytree-structured, shardable by construction.

State layout (all pytrees mirroring params):
  m, v        — f32 first/second moments
  master      — f32 master copy of bf16 params (optional; bf16 training
                without masters stalls once |update| < bf16 ulp)
  step        — scalar int32

Sharding: every state tensor inherits the *parameter's* logical axes, so
FSDP rules shard optimizer state exactly like ZeRO-3 — no special casing.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_weights: bool = True
    warmup_steps: int = 100
    # cosine decay horizon; 0 disables the schedule (constant lr)
    decay_steps: int = 0


def schedule(cfg: OptimizerConfig, step):
    lr = jnp.asarray(cfg.lr, jnp.float32)
    s = step.astype(jnp.float32)
    if cfg.warmup_steps > 0:
        lr = lr * jnp.minimum(1.0, (s + 1.0) / cfg.warmup_steps)
    if cfg.decay_steps > 0:
        frac = jnp.clip(
            (s - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        lr = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return lr


def init_opt_state(cfg: OptimizerConfig, params) -> dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_weights:
        # copy=True: an f32 param would otherwise ALIAS its master, and
        # donating the state then donates one buffer twice
        state["master"] = jax.tree_util.tree_map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params
        )
    return state


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def apply_updates(cfg: OptimizerConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.where(
        gnorm > cfg.grad_clip, cfg.grad_clip / jnp.maximum(gnorm, 1e-9), 1.0
    )
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    masters = state.get("master", params)

    def upd(p, master, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1.0 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1.0 - cfg.b2) * g32 * g32
        mh = m_new / b1c
        vh = v_new / b2c
        mst = master.astype(jnp.float32)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * mst
        mst_new = mst - lr * delta
        return mst_new.astype(p.dtype), mst_new, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat = [
        upd(p, mst, g, m, v)
        for p, mst, g, m, v in zip(
            flat_p,
            jax.tree_util.tree_leaves(masters),
            jax.tree_util.tree_leaves(grads),
            jax.tree_util.tree_leaves(state["m"]),
            jax.tree_util.tree_leaves(state["v"]),
        )
    ]
    unflat = lambda i: jax.tree_util.tree_unflatten(
        treedef, [t[i] for t in flat]
    )
    new_params, new_master = unflat(0), unflat(1)
    new_state = {"m": unflat(2), "v": unflat(3), "step": step}
    if cfg.master_weights:
        new_state["master"] = new_master
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

"""1-bit gradient compression: error-feedback signSGD (EF-signSGD).

Thematic tie to the paper: PiC-BNN binarizes weights and activations;
EF-signSGD binarizes the *gradient exchange* — each tensor is reduced to
sign bits plus one f32 scale, with the quantization error fed back into
the next step's gradient (Karimireddy et al. 2019).  On a 2-pod mesh the
cross-pod (DCN) gradient traffic drops ~32x — DCN is the scarce resource
at multi-pod scale, exactly as the matchline was the scarce resource in
silicon.

Implementation notes:
  * the error-feedback residual lives in the train state implicitly via
    closure-free functional form: compress() takes and returns the
    residual pytree;
  * `maybe_compress_grads` is the train_step hook: identity when off;
  * compression is applied AFTER the data-parallel mean (GSPMD inserts
    the intra-pod reduce), modeling sign-compression of the slow (pod)
    axis exchange.  The simulation is numerically faithful: values are
    quantized exactly as the wire format would carry them.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    # per-tensor scale: "mean_abs" (signSGD-SI) or "l2" (scaled-sign)
    scale: str = "mean_abs"


def sign_compress(x, scale: str = "mean_abs"):
    """x -> (sign bits as +-1 in x.dtype, scalar scale)."""
    xf = x.astype(jnp.float32)
    if scale == "mean_abs":
        s = jnp.mean(jnp.abs(xf))
    else:
        s = jnp.linalg.norm(xf) / jnp.sqrt(jnp.maximum(xf.size, 1))
    return jnp.where(xf >= 0, 1.0, -1.0), s


def sign_decompress(bits, s, dtype=jnp.float32):
    return (bits * s).astype(dtype)


def compress_with_feedback(grads, residual, scale: str = "mean_abs"):
    """EF-signSGD: quantize (grad + residual); return (g_hat, new_residual)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        bits, s = sign_compress(gf, scale)
        g_hat = sign_decompress(bits, s)
        return g_hat, gf - g_hat

    out = jax.tree_util.tree_map(one, grads, residual)
    g_hat = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_res = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    return g_hat, new_res


def init_residual(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def maybe_compress_grads(cfg: CompressionConfig, grads):
    """Stateless hook used by train_step (residual-free scaled-sign).

    The residual-carrying variant (compress_with_feedback) is used by the
    supervisor loop which owns the residual state; inside the plain
    train_step we apply scaled-sign without feedback when enabled.
    """
    if not cfg.enabled:
        return grads, {}
    def one(g):
        bits, s = sign_compress(g, cfg.scale)
        return sign_decompress(bits, s, jnp.float32)
    g_hat = jax.tree_util.tree_map(one, grads)
    return g_hat, {"compressed": jnp.ones((), jnp.float32)}


def compression_ratio(params) -> float:
    """Wire-format ratio vs f32: 1 bit/element + 4 bytes/tensor."""
    leaves = jax.tree_util.tree_leaves(params)
    raw = sum(x.size * 4 for x in leaves)
    packed = sum(-(-x.size // 8) + 4 for x in leaves)
    return raw / packed

"""Training substrate: AdamW, microbatched train step, 1-bit gradient
compression (EF-signSGD)."""

from repro.train.optimizer import OptimizerConfig, init_opt_state, apply_updates  # noqa: F401
from repro.train.train_step import TrainConfig, init_train_state, make_train_step, train_step  # noqa: F401
from repro.train.grad_compress import CompressionConfig  # noqa: F401

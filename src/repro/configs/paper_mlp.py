"""The paper's own models (Sec. V-A):

  MNIST MLP        : 784 -> 128 -> 10
  Hand Gesture MLP : 4096 -> 128 -> 20

plus the Algorithm 1 ensemble settings (33 thresholds, {0, 2, ..., 64})
and `deploy_mlp`, the one-call deployment builder (train -> fold ->
persistable `deploy.Deployment`)."""

from repro.core.bnn import MLPConfig
from repro.core.ensemble import EnsembleConfig, PAPER_THRESHOLDS

MNIST_MLP = MLPConfig(layer_sizes=(784, 128, 10), bias_cells=64)
HG_MLP = MLPConfig(layer_sizes=(4096, 128, 20), bias_cells=64)

PAPER_ENSEMBLE = EnsembleConfig(
    thresholds=PAPER_THRESHOLDS, bias_cells=64, mode="fused"
)


def deploy_mlp(cfg: MLPConfig, model, *, noise=None, **kw):
    """Build the `deploy.Deployment` artifact for a paper MLP.

    Thin wrapper over `deploy.deploy` that threads the config (bias
    cells -> ensemble config).  `model` is `bnn.fold` output or a
    trained params dict (folded here); `noise` and any
    `deploy.COMPILE_OPTIONS` pass through.  `.pipeline()` compiles the
    fused classifier lazily; `.save(dir)` persists it for
    `PicBnnServer.register`.
    """
    from repro.deploy import deploy

    return deploy(model, config=cfg, noise=noise, **kw)

# Baseline software accuracies reported by the paper (Sec. V-A)
PAPER_MNIST_TOP1 = 0.952
PAPER_HG_TOP1 = 0.935
PAPER_HG_SOFTWARE_TOP1 = 0.99

"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion, VQ image tokens.  [arXiv:2405.09818; unverified]

Backbone only: the VQ-GAN image tokenizer is a frontend STUB —
input_specs() provides precomputed patch/token embeddings [B, S, D].
QK-norm enabled (chameleon's training-stability fix)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    mlp_act="swiglu",
    norm="rmsnorm",
    qk_norm=True,
    rope_theta=10000.0,
    embeds_input=True,
)

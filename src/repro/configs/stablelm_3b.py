"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32 = MHA) d_ff=6912
vocab=50304.  [hf:stabilityai/stablelm-2-1_6b; unverified]

Family notes: StableLM-2 uses LayerNorm and partial-RoPE (25%); we apply
full RoPE (recorded as an adaptation in DESIGN.md §Arch-fidelity).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    mlp_act="swiglu",
    norm="layernorm",
    rope_theta=10000.0,
)

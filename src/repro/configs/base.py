"""Model / shape configuration schema for every assigned architecture."""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerPattern:
    """Per-superblock layer layout for hybrid archs (scan unit).

    kinds: tuple over sublayers, entries in {"attn", "mamba"}.
    moe_mask: tuple[bool] — which sublayers use MoE instead of dense MLP
              (attn-kind sublayers still carry their own MLP in this arch
              family; mamba sublayers in jamba carry the MLP too).
    """

    kinds: tuple
    moe_mask: tuple
    windows: tuple = ()  # per-sublayer attention window (None = full/global)

    def __post_init__(self):
        assert len(self.kinds) == len(self.moe_mask)
        if not self.windows:
            object.__setattr__(self, "windows", (None,) * len(self.kinds))
        assert len(self.windows) == len(self.kinds)

    @property
    def size(self) -> int:
        return len(self.kinds)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # MLP
    mlp_act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    # MoE (n_experts == 0 -> dense)
    n_experts: int = 0
    moe_top_k: int = 1
    capacity_factor: float = 1.25
    moe_every: int = 1  # MoE on layers where (idx % moe_every == moe_offset)
    moe_offset: int = 0
    # attention
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None  # tokens; None = full attention
    qk_norm: bool = False  # chameleon QK-norm
    # SSM (mamba-1)
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model / 16)
    # hybrid layout (None for homogeneous stacks)
    layer_pattern: Optional[LayerPattern] = None
    # modality frontend stub: model consumes precomputed embeddings
    embeds_input: bool = False
    tie_embeddings: bool = False
    # numerics
    dtype: str = "bfloat16"
    # the paper's technique as a first-class LM feature
    binary_ffn: bool = False  # BitLinear (XNOR-popcount) FFN projections
    cam_head: bool = False  # PiC-BNN CAM-ensemble greedy-decode head
    cam_head_thresholds: int = 33
    # "votes" = PiC-BNN Algorithm 1 (binary measurements only);
    # "exact" = full-precision POPCOUNT readout over the same binary match
    #           (the ADC/TDC competitor the paper compares against)
    cam_head_mode: str = "votes"
    # remat policy for the layer scan: none | dots | full
    remat: str = "full"
    # TP partial-sum all-reduces in bf16 instead of f32 (halves the
    # activation-AR wire bytes; each partial is still f32-accumulated
    # inside the MXU before rounding) — §Perf variant, off by default
    tp_ar_bf16: bool = False
    # attention kv-chunk for flash-style scan
    attn_chunk: int = 1024

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.dt_rank is None:
            object.__setattr__(self, "dt_rank", -(-self.d_model // 16))

    # -- derived ------------------------------------------------------------
    @property
    def is_attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def jax_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def blocks(self) -> int:
        """Number of scan steps (superblocks for hybrids, layers otherwise)."""
        if self.layer_pattern is not None:
            assert self.n_layers % self.layer_pattern.size == 0
            return self.n_layers // self.layer_pattern.size
        return self.n_layers

    def pattern(self) -> LayerPattern:
        """The per-scan-step layout (homogeneous stacks: one sublayer)."""
        if self.layer_pattern is not None:
            return self.layer_pattern
        kind = "mamba" if self.family == "ssm" else "attn"
        moe = self.n_experts > 0
        return LayerPattern(
            kinds=(kind,), moe_mask=(moe,), windows=(self.sliding_window,)
        )

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, hq, hkv = self.head_dim, self.n_heads, self.n_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (hq + 2 * hkv) + hq * hd * d
        if self.mlp_act == "swiglu":
            mlp_dense = 3 * d * f
        else:
            mlp_dense = 2 * d * f
        mlp_moe = self.n_experts * mlp_dense + d * self.n_experts
        din, n = self.d_inner, self.ssm_state
        mamba = (
            d * 2 * din  # in_proj
            + din * self.ssm_conv + din  # conv w + b
            + din * (self.dt_rank + 2 * n)  # x_proj
            + self.dt_rank * din + din  # dt_proj
            + din * n + din  # A_log, D
            + din * d  # out_proj
        )
        total = emb
        pat = self.pattern()
        for b in range(self.blocks):
            for s, kind in enumerate(pat.kinds):
                total += d  # norm scale
                if kind == "attn":
                    total += attn
                    has_ffn = True
                else:
                    total += mamba
                    has_ffn = self.family == "hybrid"
                if has_ffn:
                    total += d  # norm2
                    total += mlp_moe if pat.moe_mask[s] else mlp_dense
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_expert = (3 if self.mlp_act == "swiglu" else 2) * d * f
        inactive = 0
        pat = self.pattern()
        for b in range(self.blocks):
            for s in range(pat.size):
                if pat.moe_mask[s]:
                    inactive += (self.n_experts - self.moe_top_k) * per_expert
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

ALL_SHAPES: Sequence[ShapeConfig] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def long_context_applicable(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (SSM / hybrid /
    sliding-window / chunked-local attention); pure full-attention archs
    are skipped per the assignment (recorded in DESIGN.md)."""
    if cfg.family in ("ssm", "hybrid"):
        return True
    if cfg.sliding_window is not None:
        return True
    if cfg.layer_pattern is not None and any(
        w is not None for w in cfg.layer_pattern.windows
    ):
        # mostly-local interleaves (llama4): global layers' caches are
        # sequence-sharded; local layers hold rolling windows
        return True
    return False


def applicable_shapes(cfg: ModelConfig) -> list[ShapeConfig]:
    out = []
    for s in ALL_SHAPES:
        if s.name == "long_500k" and not long_context_applicable(cfg):
            continue
        out.append(s)
    return out

"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE.  [arXiv:2402.19173; hf]

GELU MLP + LayerNorm (bigcode family).  The assignment classifies this
arch as pure full attention (long_500k skipped) — we follow that reading
and do not model the optional 4k sliding window of the release."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    mlp_act="gelu",
    norm="layernorm",
    rope_theta=100000.0,
)

"""musicgen-medium [audio] — 48L d_model=1536 24H (GQA kv=24 = MHA)
d_ff=6144 vocab=2048 — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]

Backbone only: the EnCodec tokenizer is a frontend STUB — input_specs()
provides precomputed frame embeddings.  The 2048-entry codebook is the
natural CAM-head demonstrator: 2048 classes = one 2048x64 PiC-BNN bank
configuration (see configs/musicgen_cam.py for the technique-enabled
variant used in §Perf)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    mlp_act="gelu",
    norm="layernorm",
    rope_theta=10000.0,
    embeds_input=True,
)

"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Modeled per the llama4 family layout:
  * 3 local (chunked, 8192-token window) : 1 global attention interleave
    (iRoPE), expressed as a 4-sublayer scan pattern;
  * MoE every other layer (interleave_moe_layer_step=2), dense otherwise;
  * the shared expert is folded into the routed experts (DESIGN.md
    §Arch-fidelity).
The mostly-local pattern makes long_500k runnable: local layers keep an
8k rolling cache; the 12 global layers hold sequence-sharded full caches."""

from repro.configs.base import LayerPattern, ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    moe_top_k=1,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=500000.0,
    layer_pattern=LayerPattern(
        kinds=("attn", "attn", "attn", "attn"),
        moe_mask=(False, True, False, True),
        windows=(8192, 8192, 8192, None),
    ),
)

"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE.
[arXiv:2403.19887; hf]

HF layout: attn_layer_period=8, attn_layer_offset=4 (one attention layer
per 8, at index 4); expert_layer_period=2, expert_layer_offset=1 (MoE on
odd layers).  Expressed as a scanned 8-sublayer superblock x 4."""

from repro.configs.base import LayerPattern, ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    moe_top_k=2,
    mlp_act="swiglu",
    norm="rmsnorm",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    layer_pattern=LayerPattern(
        kinds=(
            "mamba", "mamba", "mamba", "mamba",
            "attn", "mamba", "mamba", "mamba",
        ),
        moe_mask=(False, True, False, True, False, True, False, True),
    ),
)

"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3.  [hf:meta-llama/Llama-3.2-1B; unverified]

head_dim=64 (2048/32); embeddings tied (as in the released model)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=500000.0,
    tie_embeddings=True,
)

"""End-to-end-binary CNN configs for the paper's two image tasks.

The paper's headline property is *end-to-end* binarization: unlike
typical BNNs that keep "the input layer of a convolutional neural
network" in full precision, every layer here — input included — computes
on bits.  These configs instantiate that claim as small binary CNNs over
the same synthetic stand-in datasets the MLP workload uses
(`data/synthetic.py`):

  MNIST CNN (28x28, 10 classes):
      thermometer-8 input -> 3x3x32 s2 conv -> 3x3x32 s2 conv
      -> flatten 1152 -> FC 128 -> CAM head (10 rows, 33-pass vote)
  HG CNN (64x64, 20 classes):
      thermometer-4 input -> 3x3x32 s2 conv -> 3x3x32 s2 conv
      -> flatten 7200 -> FC 128 -> CAM head (20 rows, 33-pass vote)

Downsampling is stride-2 VALID convs (no pooling — pooling would need a
majority unit outside the binary-matching machinery).  Conv channel
counts are multiples of 32 so the conv->FC flatten is word-aligned
(DESIGN.md §10); the head row (128 + 64 bias cells) lands on the macro's
1024x128 logical bank configuration, same as the paper MLPs.

`build_cnn_pipeline` is the one-call deployment path used by the
benchmarks, the serving registry, and the tests.
"""

from __future__ import annotations

from repro.core.binarize import InputEncoding
from repro.core.convnet import CNNConfig, ConvSpec
from repro.core.ensemble import EnsembleConfig, PAPER_THRESHOLDS

MNIST_CNN = CNNConfig(
    side=28,
    encoding=InputEncoding("thermometer", 8),
    conv=(ConvSpec(3, 32, 2), ConvSpec(3, 32, 2)),
    hidden=(128,),
    n_classes=10,
    bias_cells=64,
)

HG_CNN = CNNConfig(
    side=64,
    encoding=InputEncoding("thermometer", 4),
    conv=(ConvSpec(3, 32, 2), ConvSpec(3, 32, 2)),
    hidden=(128,),
    n_classes=20,
    bias_cells=64,
)

CNN_ENSEMBLE = EnsembleConfig(
    thresholds=PAPER_THRESHOLDS, bias_cells=64, mode="fused"
)


def deploy_cnn(cfg: CNNConfig, model, *, noise=None, **kw):
    """Build the `deploy.Deployment` artifact for an end-to-end CNN.

    Thin wrapper over `deploy.deploy` that threads the config's image
    geometry, binary input encoding, and bias cells (the conv-aware bq
    default — 64, DESIGN.md §10 — comes from compile_pipeline itself).
    `model` is `convnet.fold_cnn` (trained), a trained params dict
    (folded here), or `convnet.random_folded_cnn` (weight-agnostic
    benchmarks/tests) output.  `.pipeline()` compiles lazily;
    `.save(dir)` persists for `PicBnnServer.register`.
    """
    from repro.deploy import deploy

    return deploy(model, config=cfg, noise=noise, **kw)


def build_cnn_pipeline(cfg: CNNConfig, folded, *, impl=None, bq=None,
                       noise=None, **kw):
    """Compile a folded CNN into the fused end-to-end pipeline.

    `deploy_cnn(...).pipeline()` in one call — kept as the historical
    one-call deployment path used by benchmarks and tests.
    """
    opts = {k: v for k, v in dict(impl=impl, bq=bq, **kw).items()
            if v is not None}
    return deploy_cnn(cfg, folded, noise=noise, **opts).pipeline()

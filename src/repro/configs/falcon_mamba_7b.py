"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16 — mamba1 arch.  [arXiv:2410.05355; unverified]

d_inner = 2 * 4096 = 8192; conv kernel 4; dt_rank = ceil(4096/16) = 256.
Decode is O(1) in context length => long_500k is the showcase shape.
The paper's technique applies to in/out projections + head only; the
selective-scan recurrence is not a matching operation (DESIGN.md
§Arch-applicability)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,  # unused (attn-free); kept for schema uniformity
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    norm="rmsnorm",
)

"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2 — 8 experts top-2, SWA.  [arXiv:2401.04088; hf]

Sliding-window attention (4096) on every layer => long_500k runs with a
rolling window cache."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    moe_top_k=2,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    sliding_window=4096,
)

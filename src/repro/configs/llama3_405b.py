"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 — GQA, 128k vocab.  [arXiv:2407.21783; unverified]

The scale driver of the dry-run sweep: 405B params => FSDP+TP is
mandatory; single-pod v5e training memory is analysed in EXPERIMENTS.md."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=500000.0,
)

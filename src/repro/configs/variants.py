"""Technique-enabled and reduced (smoke-test) config variants."""

from __future__ import annotations

import dataclasses
import math

from repro.configs.base import LayerPattern, ModelConfig


def with_binary_ffn(cfg: ModelConfig) -> ModelConfig:
    """BitLinear (XNOR-popcount) FFN variant of any arch."""
    return dataclasses.replace(
        cfg, name=cfg.name + "+binary-ffn", binary_ffn=True
    )


def with_cam_head(cfg: ModelConfig, mode: str = "votes") -> ModelConfig:
    """PiC-BNN CAM-ensemble greedy-decode head variant.

    mode="exact" gives the ADC/TDC-readout competitor baseline."""
    suffix = "+cam-head" if mode == "votes" else "+cam-head-exact"
    return dataclasses.replace(
        cfg, name=cfg.name + suffix, cam_head=True, cam_head_mode=mode
    )


def reduced(cfg: ModelConfig, *, blocks: int = 2) -> ModelConfig:
    """Smoke-test configuration: same family/pattern, tiny dimensions.

    Keeps the structural properties under test (GQA ratio, MoE routing,
    hybrid interleave, window pattern) while shrinking every width so one
    forward/train step runs in milliseconds on CPU.
    """
    pat = cfg.pattern()
    # preserve the GQA ratio where possible
    ratio = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
    n_heads = 4 if ratio <= 4 else ratio
    n_kv = max(n_heads // ratio, 1)
    new_pattern = None
    if cfg.layer_pattern is not None:
        new_pattern = LayerPattern(
            kinds=pat.kinds,
            moe_mask=pat.moe_mask,
            windows=tuple(
                None if w is None else min(w, 16) for w in pat.windows
            ),
        )
    return dataclasses.replace(
        cfg,
        name=cfg.name + "+smoke",
        n_layers=blocks * pat.size,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=cfg.d_ff and 128,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 4),
        moe_top_k=min(cfg.moe_top_k, 2),
        sliding_window=None if cfg.sliding_window is None else 16,
        layer_pattern=new_pattern,
        dt_rank=8,
        dtype="float32",
        remat="none",
        attn_chunk=8,
        cam_head_thresholds=9,
    )

"""Architecture registry: --arch <id> resolution for every launcher."""

from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    LayerPattern,
    ModelConfig,
    ShapeConfig,
    applicable_shapes,
    long_context_applicable,
)
from repro.configs import variants  # noqa: F401
from repro.configs.chameleon_34b import CONFIG as _chameleon
from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba
from repro.configs.jamba_52b import CONFIG as _jamba
from repro.configs.llama3_2_1b import CONFIG as _llama32_1b
from repro.configs.llama3_405b import CONFIG as _llama3_405b
from repro.configs.llama4_maverick import CONFIG as _llama4
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.musicgen_medium import CONFIG as _musicgen
from repro.configs.stablelm_3b import CONFIG as _stablelm
from repro.configs.starcoder2_15b import CONFIG as _starcoder2

REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _stablelm,
        _llama32_1b,
        _starcoder2,
        _llama3_405b,
        _llama4,
        _mixtral,
        _falcon_mamba,
        _jamba,
        _chameleon,
        _musicgen,
    )
}

# short aliases for the CLI
ALIASES = {
    "stablelm-3b": "stablelm-3b",
    "llama3.2-1b": "llama3.2-1b",
    "starcoder2-15b": "starcoder2-15b",
    "llama3-405b": "llama3-405b",
    "llama4-maverick": "llama4-maverick-400b-a17b",
    "llama4-maverick-400b-a17b": "llama4-maverick-400b-a17b",
    "mixtral-8x7b": "mixtral-8x7b",
    "falcon-mamba-7b": "falcon-mamba-7b",
    "jamba-v0.1-52b": "jamba-v0.1-52b",
    "jamba-52b": "jamba-v0.1-52b",
    "chameleon-34b": "chameleon-34b",
    "musicgen-medium": "musicgen-medium",
}


def get_config(name: str) -> ModelConfig:
    base, *mods = name.split("+")
    cfg = REGISTRY[ALIASES.get(base, base)]
    for mod in mods:
        if mod == "binary-ffn":
            cfg = variants.with_binary_ffn(cfg)
        elif mod == "cam-head":
            cfg = variants.with_cam_head(cfg)
        elif mod == "cam-head-exact":
            cfg = variants.with_cam_head(cfg, mode="exact")
        elif mod == "bf16ar":
            import dataclasses

            cfg = dataclasses.replace(
                cfg, name=cfg.name + "+bf16ar", tp_ar_bf16=True
            )
        elif mod == "smoke":
            cfg = variants.reduced(cfg)
        else:
            raise KeyError(f"unknown config modifier {mod!r}")
    return cfg


def list_archs() -> list[str]:
    return sorted(REGISTRY)

"""Declarative inference request spec for the compiled PiC-BNN pipeline.

The paper's deployment contract is ONE search primitive — Algorithm 1
with knob-configured noise — yet the pipeline API had grown an eight-way
method family (`votes`, `votes(key=)`, `votes_each`, `votes_mc`,
`votes_mc_each`, `votes_mc_each_sum`, `cum_votes`, `predict*`), each
re-implementing the same bucket/pad/trim/key glue.  :class:`InferenceSpec`
replaces that family with a value: *what to run* is data, and
`CompiledPipeline.run(x, spec, ...)` compiles-and-caches exactly one
fused program per distinct spec.

The four axes (and how the legacy family maps onto them):

    noise      — "off":        deterministic compare (no key accepted)
                 "batch":      ONE silicon draw for the whole batch
                               (`key=`; row realizations depend on batch
                               composition and bucket padding — a
                               measurement-style draw)
                 "per_request":one draw per row from `keys[i]` with
                               batch_shape=() (`keys=`; invariant to how
                               requests are coalesced — the serving
                               determinism contract)
    mc_samples — None: one realization; S >= 1: S Monte-Carlo draws with
                 the Hamming distances computed ONCE (needs a noise
                 source, so `noise != "off"`)
    reduction  — "none":   raw vote counts
                 "sum":    sum over the MC sample axis (requires
                           mc_samples — there is nothing else to sum)
                 "argmax": predicted class per row (single-realization
                           specs only)
    cumulative — per-pass cumulative votes [P, B, C] under one draw
                 (`noise="batch"`), or the exact noiseless staircase
                 (`noise="off"` — the explicit, documented form of what
                 `cum_votes` used to do by silently substituting
                 `PRNGKey(0)` on noiseless pipelines)

Every future axis (a new noise mode, a new reduction, a new workload)
is a spec field — not a ninth method.

Output shapes (B = logical batch, C = classes, P = passes, S = samples):

    ===========================  =============
    spec                         run() returns
    ===========================  =============
    reduction="none", no MC      [B, C] int32
    mc_samples=S                 [S, B, C] int32
    mc_samples=S, "sum"          [B, C] int32
    reduction="argmax"           [B] int32
    cumulative=True              [P, B, C] int32
    ===========================  =============

Specs are frozen, hashable values: they key the pipeline's program cache
and the per-(spec, bucket) warmup report.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

NOISE_MODES = ("off", "batch", "per_request")
REDUCTIONS = ("none", "sum", "argmax")


@dataclasses.dataclass(frozen=True)
class InferenceSpec:
    """Declares *what to run* against a compiled pipeline (see module doc).

    Validation happens at construction: an unsupported combination is a
    `ValueError` here, never a silently-wrong program later.  Instances
    are immutable and hashable — `CompiledPipeline` keys its compiled
    program cache on them.
    """

    noise: str = "off"
    mc_samples: Optional[int] = None
    reduction: str = "none"
    cumulative: bool = False

    def __post_init__(self):
        if self.noise not in NOISE_MODES:
            raise ValueError(
                f"spec.noise must be one of {NOISE_MODES}, got "
                f"{self.noise!r}"
            )
        if self.reduction not in REDUCTIONS:
            raise ValueError(
                f"spec.reduction must be one of {REDUCTIONS}, got "
                f"{self.reduction!r}"
            )
        if self.mc_samples is not None:
            if int(self.mc_samples) < 1:
                raise ValueError(
                    f"spec.mc_samples must be >= 1, got {self.mc_samples}"
                )
            object.__setattr__(self, "mc_samples", int(self.mc_samples))
            if self.noise == "off":
                raise ValueError(
                    "mc_samples needs a noise source: Monte-Carlo over a "
                    'deterministic compare is meaningless (noise="off")'
                )
        if self.reduction == "sum" and self.mc_samples is None:
            raise ValueError(
                'reduction="sum" sums over the Monte-Carlo sample axis; '
                "it requires mc_samples"
            )
        if self.reduction == "argmax" and self.mc_samples is not None:
            raise ValueError(
                'reduction="argmax" is single-realization only; for the '
                'MC serving aggregate use reduction="sum" and argmax the '
                "summed votes"
            )
        if self.cumulative:
            if self.mc_samples is not None or self.reduction != "none":
                raise ValueError(
                    "cumulative=True exposes the raw per-pass staircase "
                    "[P, B, C]; it composes with neither mc_samples nor "
                    "a reduction"
                )
            if self.noise == "per_request":
                raise ValueError(
                    'cumulative=True supports noise="off" (the exact '
                    'noiseless staircase) or noise="batch" (one silicon '
                    "realization); there is no per-request cumulative "
                    "entry"
                )

    # -- derived request/response contract ------------------------------
    @property
    def needs_physics(self) -> bool:
        """True when the compiled pipeline must carry a SearchPhysics."""
        return self.noise != "off"

    @property
    def needs_key(self) -> bool:
        """True when run() requires the batch-level `key=` operand."""
        return self.noise == "batch"

    @property
    def needs_keys(self) -> bool:
        """True when run() requires the per-request `keys=` operand."""
        return self.noise == "per_request"

    @property
    def batch_axis(self) -> int:
        """Axis of the program output that carries the logical batch.

        0 for [B, C] / [B] outputs; 1 when a samples or passes axis
        leads ([S, B, C] Monte-Carlo, [P, B, C] cumulative).  This is
        what lets `run()` centralize the bucket-padding trim for every
        spec instead of each legacy method hand-rolling it.
        """
        if self.cumulative:
            return 1
        if self.mc_samples is not None and self.reduction == "none":
            return 1
        return 0

    def describe(self) -> str:
        """Compact human-readable tag (used in warmup/serving reports)."""
        parts = [f"noise={self.noise}"]
        if self.mc_samples is not None:
            parts.append(f"mc={self.mc_samples}")
        if self.reduction != "none":
            parts.append(self.reduction)
        if self.cumulative:
            parts.append("cumulative")
        return "spec(" + ",".join(parts) + ")"


#: common request shapes, by name (also the shims' targets)
VOTES = InferenceSpec()
PREDICT = InferenceSpec(reduction="argmax")
CUM_VOTES = InferenceSpec(cumulative=True)


def legacy_entry_spec(name: str,
                      mc_samples: Optional[int] = None) -> InferenceSpec:
    """The `InferenceSpec` equivalent of a legacy entry-point name.

    The eight-method family collapses onto the spec axes as follows
    (`predict`/`predict_each` are the argmax reductions of `votes` /
    `votes_each`):

        votes             -> InferenceSpec()
        votes_noisy       -> InferenceSpec(noise="batch")        # votes(key=)
        votes_each        -> InferenceSpec(noise="per_request")
        votes_mc          -> InferenceSpec(noise="batch", mc_samples=S)
        votes_mc_each     -> InferenceSpec(noise="per_request", mc_samples=S)
        votes_mc_each_sum -> ... mc_samples=S, reduction="sum"
        cum_votes         -> InferenceSpec(noise="batch", cumulative=True)
        predict           -> InferenceSpec(reduction="argmax")
        predict_each      -> InferenceSpec(noise="per_request",
                                           reduction="argmax")

    `mc_samples` is required for the `votes_mc*` names and rejected
    otherwise.  Used by the deprecated warmup `entries=` translation and
    documented as the migration table in README.md.
    """
    table = {
        "votes": dict(),
        "votes_noisy": dict(noise="batch"),
        "votes_each": dict(noise="per_request"),
        "votes_mc": dict(noise="batch", mc=True),
        "votes_mc_each": dict(noise="per_request", mc=True),
        "votes_mc_each_sum": dict(noise="per_request", mc=True,
                                  reduction="sum"),
        "cum_votes": dict(noise="batch", cumulative=True),
        "predict": dict(reduction="argmax"),
        "predict_each": dict(noise="per_request", reduction="argmax"),
    }
    entry = table.get(name)
    if entry is None:
        raise ValueError(
            f"unknown legacy entry {name!r}; known: {sorted(table)}"
        )
    wants_mc = entry.pop("mc", False)
    if wants_mc and mc_samples is None:
        raise ValueError(f"legacy entry {name!r} needs mc_samples=")
    if not wants_mc and mc_samples is not None:
        raise ValueError(f"legacy entry {name!r} takes no mc_samples")
    return InferenceSpec(
        noise=entry.get("noise", "off"),
        mc_samples=mc_samples if wants_mc else None,
        reduction=entry.get("reduction", "none"),
        cumulative=entry.get("cumulative", False),
    )

"""End-to-end deployed-BNN inference pipeline (packed domain, fused).

`compile_pipeline(folded, ens_cfg)` turns a folded binary MLP (list of
`bnn.FoldedLayer`) plus an Algorithm-1 ensemble config into a jitted
batch classifier:

    pipe = compile_pipeline(folded, EnsembleConfig())
    votes = pipe.votes(x_pm1)     # [B, n_classes] int32 vote counts
    pred  = pipe.predict(x_pm1)   # [B] int32 argmax classes

Semantics are bit-exact equal to the digital oracle
(`bnn.folded_forward_exact` hidden layers + `ensemble.votes_fused` head);
tests/test_pipeline.py asserts this across bank configurations.

Silicon mode: `compile_pipeline(folded, cfg, noise=SILICON)` threads the
unified device physics (`core/physics.SearchPhysics`) through the SAME
fused program — per-pass effective thresholds are sampled as [P, B, C]
float arrays (sigma_hd per row; sigma_vref / sigma_tjitter pass-global
through the Table-I knob schedule; temp_drift_hd systematic) and only the
head compare changes, so the HD-once/compare-33x amortization survives
noise.  `votes(x, key=...)` draws one silicon realization;
`votes_mc(x, key, n_samples)` vmaps the draw for Monte-Carlo evaluation
with the Hamming distances computed ONCE across all samples;
`cum_votes(x, key)` exposes the per-pass cumulative votes that noisy
Fig.-5-style truncated sweeps need (`ensemble.sweep_from_votes` is
noiseless-only — see its docstring).  With `noise=NOISELESS` every noisy
entry point is bit-identical to the noiseless oracle (tested).

Two fused implementations, selected by `impl` (default: by backend):

  pallas — kernels/fused_mlp.py: one kernel launch per batch block,
           hidden activations resident in VMEM (the TPU deployment path;
           runs under interpret mode elsewhere, for semantics only).  The
           noisy path feeds the kernel a precomputed [B, C, P]
           threshold-sample operand — randomness never enters the kernel.
  xla    — the same packed-domain math as a single jitted XLA program:
           activations stay uint32-packed between layers and the whole
           net fuses into one executable (the portable fast path — on
           CPU this is what beats the layer-by-layer unpacked flow; see
           benchmarks/e2e_throughput.py).  The noisy path broadcasts the
           sampled [P, B, C] thresholds against the one HD computation.

`votes_mc` / `cum_votes` always use the XLA-twin math (per-pass outputs
do not fit the kernel's single [B, C] result block); the twins are
bit-exact equal so this is a pure scheduling choice.

Convolutional graphs: `folded` may start with a prefix of
`convnet.FoldedConvLayer` (a deployed end-to-end-binary CNN, e.g.
`convnet.fold_cnn` output).  The pipeline then takes RAW [0,1] pixels
[B, side*side]: the binary input layer (`image_encoding`, thermometer by
default) and the channel packing run inside the jitted `_pack_fn`, the
conv stack executes in the packed domain (`kernels/fused_conv.py` on the
pallas path, the same shared math as one XLA program otherwise), and the
flatten feeds the FC stage — so every entry point below (votes, silicon
votes(key=), votes_mc, cum_votes, the votes_each serving family) works
identically for conv and MLP deployments.  Bit-exactness bar: the
unpacked oracle `kernels.ref.conv_votes_ref` (tests/test_conv.py).

Batch-size bucketing: inputs are zero-padded up to the next bucket
(powers of two, floor `min_bucket`) so a serving loop with ragged batch
sizes compiles O(log B) program variants instead of one per size.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize
from repro.core.bnn import FoldedLayer
from repro.core.convnet import FoldedConvLayer
from repro.core.device_model import NoiseModel
from repro.core.ensemble import CAMEnsembleHead, EnsembleConfig, build_head
from repro.core.physics import SearchPhysics
from repro.kernels import fused_conv, fused_mlp


def next_bucket(n: int, min_bucket: int = 64,
                max_bucket: Optional[int] = None) -> int:
    """Smallest power-of-two bucket >= n (floored at min_bucket).

    n == 0 is rejected (an empty batch has no bucket — dispatching it
    would burn a full min_bucket of padded compute for zero results), as
    is exceeding the explicit `max_bucket` cap: a serving loop sets the
    cap to its max batch so the compiled-variant set is closed (warmup
    covers every bucket) and an oversized dispatch fails loudly instead
    of silently compiling a new program variant mid-traffic.
    """
    if n <= 0:
        raise ValueError(f"batch size must be >= 1, got {n}")
    b = min_bucket
    while b < n:
        b *= 2
    if max_bucket is not None and b > max_bucket:
        raise ValueError(
            f"batch {n} needs bucket {b} > max_bucket {max_bucket}; "
            "split the batch or recompile with a larger cap"
        )
    return b


def bucket_grid(max_batch: int, min_bucket: int = 64) -> tuple[int, ...]:
    """Every bucket a batch in 1..max_batch can land on (ascending)."""
    out = [min_bucket]
    while out[-1] < max_batch:
        out.append(out[-1] * 2)
    return tuple(out)


def _head_hd_xla(x_packed, layer_ws, layer_cs, layer_n_bits, head_rows,
                 bias_cells: int):
    """Packed-domain fused forward up to the head Hamming distances.

    Same math as the Pallas kernel: XNOR-popcount matvec + C + sign +
    repack per hidden layer, then HD of the (bias-appended) head query
    against every class row.  Returns [B, C] int32 — the one quantity
    every vote path (noiseless, noisy, Monte-Carlo, cumulative) compares
    thresholds against.
    """
    q = x_packed
    n_layers = len(layer_ws)
    for i, (w, c, n_bits) in enumerate(zip(layer_ws, layer_cs, layer_n_bits)):
        hd = binarize.hamming_packed(q[:, None, :], w)
        y = (n_bits - 2 * hd) + c[None, :]
        bits = (y >= 0).astype(jnp.uint8)
        if i + 1 == n_layers:  # head query: append bias drive bits
            ones = jnp.ones((bits.shape[0], bias_cells), jnp.uint8)
            bits = jnp.concatenate([bits, ones], axis=-1)
        q = binarize.pack_bits(bits)
        # align packed width with the next operand's (zero pad words)
        kw_next = (head_rows if i + 1 == n_layers else layer_ws[i + 1]).shape[1]
        if q.shape[1] < kw_next:
            q = jnp.pad(q, ((0, 0), (0, kw_next - q.shape[1])))
    return binarize.hamming_packed(q[:, None, :], head_rows)


@dataclasses.dataclass
class CompiledPipeline:
    """A jitted end-to-end batch classifier for one deployed BNN."""

    head: CAMEnsembleHead
    n_in: int
    n_classes: int
    impl: str
    min_bucket: int
    head_only: bool  # no hidden layers: input feeds the CAM head directly
    physics: Optional[SearchPhysics]  # None <=> compiled without noise=
    _votes_packed: Callable  # [Bp, Kw0] uint32 -> [Bp, C] int32 (jitted)
    _votes_noisy_packed: Optional[Callable] = None  # (x, key) -> [Bp, C]
    _votes_mc_packed: Optional[Callable] = None  # (x, key, S) -> [S, Bp, C]
    _cum_votes_packed: Optional[Callable] = None  # (x, key) -> [P, Bp, C]
    _votes_each_packed: Optional[Callable] = None  # (x, keys[B,2]) -> [Bp, C]
    _votes_mc_each_packed: Optional[Callable] = None  # (x, keys, S)
    _votes_mc_each_sum_packed: Optional[Callable] = None  # -> [Bp, C]
    _pack_fn: Optional[Callable] = None  # jitted ±1 [B, n_in] -> packed
    max_bucket: Optional[int] = None  # serving cap on the bucket grid

    def _pack_input(self, x_pm1: jax.Array) -> jax.Array:
        # one jitted dispatch: the eager op-by-op pack costs ~5x the whole
        # fused vote program in host dispatch overhead (serving hot path)
        return self._pack_fn(jnp.asarray(x_pm1))

    def _bucketed(self, x_packed: jax.Array):
        b = x_packed.shape[0]
        bp = next_bucket(b, self.min_bucket, self.max_bucket)
        if bp != b:
            x_packed = jnp.pad(x_packed, ((0, bp - b), (0, 0)))
        return x_packed, b

    def buckets_for(self, max_batch: int) -> tuple[int, ...]:
        """The bucket grid batches 1..max_batch dispatch into."""
        return bucket_grid(max_batch, self.min_bucket)

    #: every warmable entry point; "votes" is the noiseless path, the
    #: rest need a silicon-mode pipeline ("votes_mc*" also mc_samples)
    WARMUP_ENTRIES = ("votes", "votes_noisy", "votes_each", "votes_mc",
                      "votes_mc_each", "votes_mc_each_sum")

    def warmup(self, max_batch: int, *, key: Optional[jax.Array] = None,
               mc_samples: Optional[int] = None, device=None,
               entries: Optional[Sequence[str]] = None) -> dict[int, float]:
        """Precompile every bucket a batch <= max_batch can land on.

        Runs one dummy batch per bucket through the selected compiled
        entry points and blocks until ready, so first-request compile
        latency never shows up in served percentiles.

        entries : subset of WARMUP_ENTRIES; default warms everything the
            pipeline supports (noiseless votes; plus votes(key=) /
            votes_each, and the votes_mc* family when `mc_samples` is
            given, on a silicon-mode pipeline).  A serving loop passes
            exactly its dispatch path — each entry is a separate XLA
            compile per bucket, and startup time is entries x buckets x
            devices.
        device  : commits the dummy operands — a device for round-robin
            fan-out, or a `jax.sharding.Sharding` for SPMD fan-out (jit
            caches key on input sharding, so warming with a different
            placement than dispatch would never hit).  Scalar keys are
            replicated when a sharding is given (a [2] key cannot take a
            batch-axis shard).

        Returns {bucket: seconds} — dominated by compile time on first
        call, ~free when already cached.
        """
        if entries is None:
            entries = ("votes",) if self.physics is None else (
                self.WARMUP_ENTRIES if mc_samples
                else ("votes", "votes_noisy", "votes_each")
            )
        unknown = set(entries) - set(self.WARMUP_ENTRIES)
        if unknown:
            raise ValueError(f"unknown warmup entries {sorted(unknown)}")
        if any(e != "votes" for e in entries):
            self._require_physics("warmup of silicon entries")
        if any(e.startswith("votes_mc") for e in entries) and not mc_samples:
            raise ValueError("votes_mc* warmup entries need mc_samples=")

        replicated = None
        if isinstance(device, jax.sharding.NamedSharding):
            from jax.sharding import PartitionSpec

            replicated = jax.sharding.NamedSharding(device.mesh,
                                                    PartitionSpec())
        times: dict[int, float] = {}
        for b in self.buckets_for(max_batch):
            x = jnp.ones((b, self.n_in), jnp.float32)
            k = key if key is not None else jax.random.PRNGKey(0)
            keys = jax.random.split(k, b)
            if device is not None:
                x = jax.device_put(x, device)
                k = jax.device_put(k, replicated or device)
                keys = jax.device_put(keys, device)  # batch-sharded like x
            t0 = time.perf_counter()
            if "votes" in entries:
                jax.block_until_ready(self.votes(x))
            if "votes_noisy" in entries:
                jax.block_until_ready(self.votes(x, k))
            if "votes_each" in entries:
                jax.block_until_ready(self.votes_each(x, keys))
            if "votes_mc" in entries:
                jax.block_until_ready(self.votes_mc(x, k, mc_samples))
            if "votes_mc_each" in entries:
                jax.block_until_ready(
                    self.votes_mc_each(x, keys, mc_samples)
                )
            if "votes_mc_each_sum" in entries:
                jax.block_until_ready(
                    self.votes_mc_each_sum(x, keys, mc_samples)
                )
            times[b] = time.perf_counter() - t0
        return times

    def _require_physics(self, what: str) -> SearchPhysics:
        if self.physics is None:
            raise ValueError(
                f"{what} needs a silicon-mode pipeline: recompile with "
                "compile_pipeline(..., noise=<NoiseModel>)"
            )
        return self.physics

    def votes(self, x_pm1: jax.Array, key: Optional[jax.Array] = None):
        """Vote counts for an input batch [B, n_in] -> [B, C] int32.

        Input domain: ±1 activations for MLP pipelines; RAW [0,1] pixels
        for conv pipelines (n_in = image_side**2 — the binary input
        encoding and channel packing run inside the jitted pack step).

        With `key` (requires a `noise=`-compiled pipeline) the votes are
        one silicon-noise realization; with the NOISELESS model this path
        is bit-identical to the noiseless one.
        """
        return self.votes_packed(self._pack_input(x_pm1), key)

    @staticmethod
    def _trim(out: jax.Array, b: int) -> jax.Array:
        # slicing is an eager XLA op per call — skip it when the batch
        # already fills its bucket (the serving hot path by construction)
        return out if out.shape[0] == b else out[:b]

    def votes_packed(self, x_packed: jax.Array,
                     key: Optional[jax.Array] = None) -> jax.Array:
        """Vote counts for an already-packed input batch [B, Kw0].

        Conv pipelines: Kw0 = side*side*Cw0, the row-flattened channel-
        packed encoded image the jitted pack step emits (`_pack_input`).
        """
        x_packed, b = self._bucketed(x_packed)
        if key is None:
            return self._trim(self._votes_packed(x_packed), b)
        self._require_physics("votes(key=...)")
        return self._trim(self._votes_noisy_packed(x_packed, key), b)

    def votes_mc(self, x_pm1: jax.Array, key: jax.Array,
                 n_samples: int) -> jax.Array:
        """Monte-Carlo silicon-noise votes: [n_samples, B, C] int32.

        One fused program: the packed forward + Hamming distances run
        ONCE, then `n_samples` independent threshold realizations are
        drawn (vmapped) and compared in-register — this is what replaces
        `n_samples` sequential `votes_faithful` sweeps (benchmarks record
        the speedup in BENCH_noise.json).
        """
        self._require_physics("votes_mc")
        x_packed, b = self._bucketed(self._pack_input(x_pm1))
        out = self._votes_mc_packed(x_packed, key, int(n_samples))
        return out if out.shape[1] == b else out[:, :b]

    def _each_keys(self, keys, b: int, bp: int) -> jax.Array:
        keys = jnp.asarray(keys)
        if keys.ndim != 2 or keys.shape[0] != b:
            raise ValueError(
                f"keys must be [B, key_width] raw uint32 PRNG keys with "
                f"B == batch ({b}), got shape {tuple(keys.shape)} — stack "
                "jax.random.PRNGKey / jax.random.split outputs"
            )
        if bp != b:  # pad rows get (valid) zero keys; results are sliced
            keys = jnp.pad(keys, ((0, bp - b), (0, 0)))
        return keys

    def votes_each(self, x_pm1: jax.Array, keys: jax.Array) -> jax.Array:
        """Per-REQUEST silicon realizations: keys [B, 2] -> [B, C] int32.

        Row i's votes are one noise draw from keys[i] with a per-request
        (`batch_shape=()`) sample — unlike `votes(x, key)`, whose one
        batch-shaped draw makes each row's realization depend on its
        position and on the bucket padding.  `votes_each` is therefore
        invariant to batch composition: serving may coalesce requests
        into arbitrary micro-batches and still return bit-for-bit the
        votes a direct single-request call produces (the serving-engine
        determinism contract; see serve/picbnn.py).  In the NOISELESS
        limit it equals `votes(x)` exactly.
        """
        self._require_physics("votes_each")
        x_packed, b = self._bucketed(self._pack_input(x_pm1))
        keys = self._each_keys(keys, b, x_packed.shape[0])
        return self._trim(self._votes_each_packed(x_packed, keys), b)

    def votes_mc_each(self, x_pm1: jax.Array, keys: jax.Array,
                      n_samples: int) -> jax.Array:
        """Per-request Monte-Carlo votes: [n_samples, B, C] int32.

        `votes_mc` with per-request PRNG keys: request i's sample s is
        drawn from split(keys[i], n_samples)[s] with a per-request
        (`batch_shape=()`) draw, so — like `votes_each`, and unlike
        `votes_mc`'s one shared batch-shaped draw — results are invariant
        to how requests are batched.  The Hamming distances are still
        computed ONCE for the whole batch across all samples.
        Identity: votes_mc_each(x, keys, S)[s, i] ==
        votes_each(x[i:i+1], split(keys[i], S)[s:s+1])[0] (tested).
        """
        self._require_physics("votes_mc_each")
        x_packed, b = self._bucketed(self._pack_input(x_pm1))
        keys = self._each_keys(keys, b, x_packed.shape[0])
        out = self._votes_mc_each_packed(x_packed, keys, int(n_samples))
        return out if out.shape[1] == b else out[:, :b]

    def votes_mc_each_sum(self, x_pm1: jax.Array, keys: jax.Array,
                          n_samples: int) -> jax.Array:
        """votes_mc_each summed over samples, [B, C] int32 — the MC
        serving aggregate, with the reduction fused into the jitted
        program (an eager .sum(0) per dispatch would compile mid-traffic
        and cost a host dispatch on the serving hot path)."""
        self._require_physics("votes_mc_each_sum")
        x_packed, b = self._bucketed(self._pack_input(x_pm1))
        keys = self._each_keys(keys, b, x_packed.shape[0])
        return self._trim(
            self._votes_mc_each_sum_packed(x_packed, keys, int(n_samples)),
            b,
        )

    def predict_each(self, x_pm1: jax.Array, keys: jax.Array) -> jax.Array:
        """Per-request-key Algorithm 1 prediction (argmax of votes_each)."""
        return jnp.argmax(self.votes_each(x_pm1, keys), axis=-1)

    def cum_votes(self, x_pm1: jax.Array,
                  key: Optional[jax.Array] = None) -> jax.Array:
        """Per-pass cumulative votes [P, B, C] under one noise draw.

        The silicon-conditioned replacement for
        `ensemble.sweep_from_votes` (which is valid ONLY noiseless):
        per-pass match indicators are materialized from the sampled
        thresholds and cumsum'd, at fused speed.  key=None is allowed
        only on a NOISELESS-compiled pipeline (where it gives the exact
        staircase, == sweep_from_votes of the fused total); a noisy
        pipeline must be given a key explicitly.
        """
        phys = self._require_physics("cum_votes")
        x_packed, b = self._bucketed(self._pack_input(x_pm1))
        if key is None:
            if not phys.is_noiseless:
                raise ValueError(
                    "cum_votes on a noise-compiled pipeline needs an "
                    "explicit key (each call is one silicon realization)"
                )
            key = jax.random.PRNGKey(0)  # ignored by the NOISELESS sampler
        out = self._cum_votes_packed(x_packed, key)
        return out if out.shape[1] == b else out[:, :b]

    def predict(self, x_pm1: jax.Array,
                key: Optional[jax.Array] = None) -> jax.Array:
        """Algorithm 1 prediction: per-class majority vote -> argmax."""
        return jnp.argmax(self.votes(x_pm1, key), axis=-1)

    def __call__(self, x_pm1: jax.Array,
                 key: Optional[jax.Array] = None) -> jax.Array:
        return self.predict(x_pm1, key)


def compile_pipeline(
    folded: Sequence,
    ens_cfg: EnsembleConfig | None = None,
    *,
    impl: str | None = None,
    bq: int | None = None,
    chunk: int = 4,
    min_bucket: int = 64,
    max_bucket: int | None = None,
    interpret: bool | None = None,
    noise: NoiseModel | None = None,
    params=None,
    donate: bool = False,
    image_side: int | None = None,
    image_encoding: binarize.InputEncoding | None = None,
) -> CompiledPipeline:
    """Compile a folded BNN + ensemble head into a fused batch classifier.

    folded  : `bnn.fold` output — hidden layers + the output layer (last).
              May start with a prefix of `convnet.FoldedConvLayer`
              (`convnet.fold_cnn` output): the pipeline then runs the
              end-to-end-binary CNN workload and its input domain becomes
              RAW [0,1] pixels [B, image_side**2] (the binary input
              encoding runs inside the jitted pack step).
    ens_cfg : Algorithm-1 config (thresholds / bias cells); default paper's.
    impl    : "pallas" | "xla" | None (auto: pallas on TPU, xla elsewhere —
              the Pallas kernel only *executes* off-TPU in interpret mode,
              which is for semantics tests, not speed).
    bq      : Pallas batch-block size; default 256 for MLP graphs, 64
              for conv graphs (the conv kernel's per-tap XOR temporary
              scales the VMEM working set ~4x — DESIGN.md §10 derives
              both budgets).
    noise   : optional NoiseModel — compiles the silicon-mode twins
              (votes(key=), votes_mc, cum_votes, and the per-request-key
              votes_each / votes_mc_each serving entries) with a
              SearchPhysics bundle built from the head's threshold
              schedule; `params` optionally overrides the AnalogParams.
              noise=None keeps the pipeline noiseless-only (no
              knob-schedule work at compile time).
    max_bucket : optional cap on the batch-bucket grid (see next_bucket);
              serving loops set it to their max batch so warmup() closes
              the compiled-variant set.
    donate  : donate the packed input buffer to the jitted XLA-twin
              entry points (donate_argnums) — the packing step produces
              a fresh buffer per call, so a serving loop can hand it to
              the program and save an allocation on TPU/GPU.  No effect
              on results; backends that can't reuse the buffer (CPU)
              just ignore the donation.  Off by default because
              `votes_packed` is public API and donation invalidates the
              caller's array.
    image_side : REQUIRED for conv graphs — square input image side
              (`n_in = image_side**2` raw pixels).  Rejected for pure
              MLP graphs.
    image_encoding : the binary input layer for conv graphs
              (`binarize.InputEncoding`); its width must equal the first
              conv layer's c_in.  Default: thermometer of that width.
    """
    ens_cfg = ens_cfg or EnsembleConfig()
    if len(folded) < 1:
        raise ValueError("need at least the output layer")
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown pipeline impl {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    rest = list(folded)
    conv_layers: list[FoldedConvLayer] = []
    while rest and isinstance(rest[0], FoldedConvLayer):
        conv_layers.append(rest.pop(0))
    if any(isinstance(l, FoldedConvLayer) for l in rest):
        raise ValueError("conv layers must form a prefix of `folded`")
    if not rest:
        raise ValueError("need an output FC layer after the conv stack")
    if conv_layers and image_side is None:
        raise ValueError("conv graphs need image_side=")
    if not conv_layers and (image_side is not None
                            or image_encoding is not None):
        raise ValueError("image_side/image_encoding are conv-only options")
    if bq is None:
        # the conv kernel's [bq, O, O, c_out, Cw] per-tap temporary is
        # ~4x the MLP kernel's working set per batch row (DESIGN.md §10)
        bq = 64 if conv_layers else 256

    hidden, out_layer = list(rest[:-1]), rest[-1]
    head = build_head(out_layer, ens_cfg)
    n_classes = head.n_classes

    layer_ws = tuple(
        binarize.pack_bits(jnp.asarray((l.weights_pm1 > 0).astype(np.uint8)))
        for l in hidden
    )
    layer_cs = tuple(jnp.asarray(l.c, jnp.int32) for l in hidden)
    layer_n_bits = tuple(int(l.n_in) for l in hidden)
    head_rows = head.cam.rows_packed
    thresholds = head.thresholds

    conv_metas = conv_ws = conv_cs = None
    head_direct = False
    if conv_layers:
        enc = image_encoding or binarize.InputEncoding(
            "thermometer", conv_layers[0].c_in
        )
        if enc.width != conv_layers[0].c_in:
            raise ValueError(
                f"encoding width {enc.width} != first conv c_in "
                f"{conv_layers[0].c_in}"
            )
        conv_metas = fused_conv.conv_metas_for(conv_layers, image_side)
        conv_ws = tuple(fused_conv.pack_conv_rows(l) for l in conv_layers)
        conv_cs = tuple(jnp.asarray(l.c, jnp.int32) for l in conv_layers)
        mf = conv_metas[-1]
        n_pos, c_f = mf.out_side * mf.out_side, mf.c_out
        first_fc = hidden[0] if hidden else out_layer
        if int(first_fc.n_in) != n_pos * c_f:
            raise ValueError(
                f"first FC layer n_in {first_fc.n_in} != flattened conv "
                f"features {n_pos}*{c_f}"
            )
        head_direct = not hidden
        if head_direct and c_f % 32:
            raise ValueError(
                "conv -> head-direct needs last conv c_out % 32 == 0 "
                f"(word-aligned flatten), got {c_f}"
            )
        if hidden:
            # the flatten keeps per-position word padding — repack the
            # first FC layer's rows with the matching alignment
            layer_ws = (
                fused_conv.pack_fc_rows_positionwise(
                    (hidden[0].weights_pm1 > 0).astype(np.uint8),
                    n_pos, c_f,
                ),
            ) + layer_ws[1:]
        side, cw0 = image_side, conv_metas[0].cw_in

        def _pack_conv(x01):
            img = jnp.asarray(x01).reshape(-1, side, side)
            words = binarize.pack_bits(enc.encode_bits(img))
            return words.reshape(words.shape[0], side * side * cw0)

        pack_fn = jax.jit(_pack_conv)
    elif hidden:
        pack_fn = jax.jit(binarize.pack_pm1)
    else:
        from repro.core.cam import query_with_bias

        pack_fn = jax.jit(
            functools.partial(query_with_bias, bias_cells=head.bias_cells)
        )

    phys = None
    if noise is not None:
        phys = SearchPhysics.for_head(head, noise, params)

    # donation-friendly entry points: the packed input is the only
    # per-call buffer worth donating (weights live in the closure)
    donate_kw = {"donate_argnums": (0,)} if donate else {}

    # chunk-padded operands for the XLA-twin math (also backs the
    # Monte-Carlo / cumulative paths of a pallas-impl pipeline)
    ws = tuple(fused_mlp._pad_words(w, chunk) for w in layer_ws)
    hr = fused_mlp._pad_words(head_rows, chunk)

    if conv_layers:
        bias_words = (fused_conv.bias_drive_words(head.bias_cells)
                      if head_direct else None)

        def _front(x_packed):
            # [B, S*S*Cw0] -> conv stack -> flattened packed FC query
            x4 = x_packed.reshape(-1, image_side, image_side, cw0)
            return fused_conv.conv_stage_packed(
                x4, conv_ws, conv_cs, conv_metas, bias_words
            )
    else:
        def _front(x_packed):
            return x_packed

    def _hd_xla(x_packed):
        q = _front(x_packed)
        kw0 = (ws[0] if ws else hr).shape[1]
        if q.shape[1] < kw0:
            q = jnp.pad(q, ((0, 0), (0, kw0 - q.shape[1])))
        return _head_hd_xla(
            q, ws, layer_cs, layer_n_bits, hr, head.bias_cells
        )

    if impl == "pallas" and conv_layers:
        def votes_packed_fn(x_packed):
            return fused_conv.fused_conv_votes(
                x_packed.reshape(-1, image_side, image_side, cw0),
                conv_ws, conv_cs, conv_metas,
                layer_ws, layer_cs, layer_n_bits, head_rows, thresholds,
                bias_cells=head.bias_cells, bq=bq, chunk=chunk,
                interpret=interpret, head_direct=head_direct,
            )

        @functools.partial(jax.jit, **donate_kw)
        def votes_noisy_packed_fn(x_packed, key):
            t = phys.sample(
                key, batch_shape=(x_packed.shape[0],), n_rows=n_classes
            )  # [P, B, C]
            return fused_conv.fused_conv_votes(
                x_packed.reshape(-1, image_side, image_side, cw0),
                conv_ws, conv_cs, conv_metas,
                layer_ws, layer_cs, layer_n_bits, head_rows, thresholds,
                bias_cells=head.bias_cells, bq=bq, chunk=chunk,
                interpret=interpret, head_direct=head_direct,
                thr_samples=jnp.moveaxis(t, 0, -1),  # [B, C, P] operand
            )
    elif impl == "pallas":
        def votes_packed_fn(x_packed):
            return fused_mlp.fused_mlp_votes(
                x_packed, layer_ws, layer_cs, layer_n_bits,
                head_rows, thresholds,
                bias_cells=head.bias_cells, bq=bq, chunk=chunk,
                interpret=interpret,
            )

        @functools.partial(jax.jit, **donate_kw)
        def votes_noisy_packed_fn(x_packed, key):
            t = phys.sample(
                key, batch_shape=(x_packed.shape[0],), n_rows=n_classes
            )  # [P, B, C]
            return fused_mlp.fused_mlp_votes(
                x_packed, layer_ws, layer_cs, layer_n_bits,
                head_rows, thresholds,
                bias_cells=head.bias_cells, bq=bq, chunk=chunk,
                interpret=interpret,
                thr_samples=jnp.moveaxis(t, 0, -1),  # [B, C, P] operand
            )
    else:
        @functools.partial(jax.jit, **donate_kw)
        def votes_packed_fn(x_packed):
            hd = _hd_xla(x_packed)
            return (hd[:, :, None] <= thresholds[None, None, :]).astype(
                jnp.int32
            ).sum(-1)

        @functools.partial(jax.jit, **donate_kw)
        def votes_noisy_packed_fn(x_packed, key):
            hd = _hd_xla(x_packed).astype(jnp.float32)  # [B, C]
            t = phys.sample(
                key, batch_shape=(hd.shape[0],), n_rows=n_classes
            )  # [P, B, C]
            return (hd[None] <= t).astype(jnp.int32).sum(0)

    votes_mc_packed_fn = cum_votes_packed_fn = None
    votes_each_packed_fn = votes_mc_each_packed_fn = None
    votes_mc_each_sum_packed_fn = None
    if phys is not None:
        @functools.partial(jax.jit, static_argnames=("n_samples",),
                           **donate_kw)
        def votes_mc_packed_fn(x_packed, key, n_samples: int):
            hd = _hd_xla(x_packed).astype(jnp.float32)  # [B, C] — ONCE

            def one(k):
                t = phys.sample(k, (hd.shape[0],), n_classes)  # [P, B, C]
                return (hd[None] <= t).astype(jnp.int32).sum(0)

            return jax.vmap(one)(jax.random.split(key, n_samples))

        @functools.partial(jax.jit, **donate_kw)
        def cum_votes_packed_fn(x_packed, key):
            hd = _hd_xla(x_packed).astype(jnp.float32)
            t = phys.sample(key, (hd.shape[0],), n_classes)  # [P, B, C]
            return jnp.cumsum((hd[None] <= t).astype(jnp.int32), axis=0)

        # per-request-key serving entries: one HD pass for the batch,
        # then a vmapped per-row draw with batch_shape=() — each row's
        # realization depends only on (x_i, keys_i), never on batch
        # composition or bucket padding (the serve determinism contract)
        def _votes_one(hd_i, k):
            t = phys.sample(k, (), n_classes)  # [P, C]
            return (hd_i[None] <= t).astype(jnp.int32).sum(0)

        @functools.partial(jax.jit, **donate_kw)
        def votes_each_packed_fn(x_packed, keys):
            hd = _hd_xla(x_packed).astype(jnp.float32)  # [B, C]
            return jax.vmap(_votes_one)(hd, keys)

        @functools.partial(jax.jit, static_argnames=("n_samples",),
                           **donate_kw)
        def votes_mc_each_packed_fn(x_packed, keys, n_samples: int):
            hd = _hd_xla(x_packed).astype(jnp.float32)  # [B, C] — ONCE

            def per_req(hd_i, k):
                return jax.vmap(lambda ks: _votes_one(hd_i, ks))(
                    jax.random.split(k, n_samples)
                )  # [S, C]

            return jnp.moveaxis(
                jax.vmap(per_req)(hd, keys), 1, 0
            )  # [S, B, C] (votes_mc layout)

        @functools.partial(jax.jit, static_argnames=("n_samples",),
                           **donate_kw)
        def votes_mc_each_sum_packed_fn(x_packed, keys, n_samples: int):
            hd = _hd_xla(x_packed).astype(jnp.float32)

            def per_req(hd_i, k):
                return jax.vmap(lambda ks: _votes_one(hd_i, ks))(
                    jax.random.split(k, n_samples)
                ).sum(0)  # [C] — reduction fused into the program

            return jax.vmap(per_req)(hd, keys)  # [B, C]

    if conv_layers:
        n_in = int(image_side) ** 2  # raw [0,1] pixels in, encode inside
    elif hidden:
        n_in = int(hidden[0].n_in)
    else:
        n_in = int(out_layer.n_in)
    return CompiledPipeline(
        head=head,
        n_in=n_in,
        n_classes=n_classes,
        impl=impl,
        min_bucket=min_bucket,
        head_only=not hidden,
        physics=phys,
        _votes_packed=votes_packed_fn,
        _votes_noisy_packed=votes_noisy_packed_fn if phys is not None
        else None,
        _votes_mc_packed=votes_mc_packed_fn,
        _cum_votes_packed=cum_votes_packed_fn,
        _votes_each_packed=votes_each_packed_fn,
        _votes_mc_each_packed=votes_mc_each_packed_fn,
        _votes_mc_each_sum_packed=votes_mc_each_sum_packed_fn,
        _pack_fn=pack_fn,
        max_bucket=max_bucket,
    )

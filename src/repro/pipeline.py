"""End-to-end deployed-BNN inference pipeline (packed domain, fused).

`compile_pipeline(folded, ens_cfg)` turns a folded binary MLP (list of
`bnn.FoldedLayer`) plus an Algorithm-1 ensemble config into a jitted
batch classifier driven by a declarative request spec
(`repro.spec.InferenceSpec`):

    pipe = compile_pipeline(folded, EnsembleConfig())
    votes = pipe.run(x_pm1, InferenceSpec())              # [B, C] int32
    pred  = pipe.run(x_pm1, InferenceSpec(reduction="argmax"))  # [B]

`run(x, spec, key=..., keys=...)` is the ONE entry point: it compiles
and caches exactly one fused program per distinct spec, and centralizes
the batch bucketing, pad/trim, and PRNG-key shape logic that the legacy
eight-method family (`votes`, `votes_each`, `votes_mc`, `votes_mc_each`,
`votes_mc_each_sum`, `cum_votes`, `predict`, `predict_each`) used to
copy-paste.  Those methods remain as thin deprecated shims over `run()`
for one release — bit-exact equal by construction (each shim just names
a spec) and proven so by the pre-redesign oracle tests.

Semantics are bit-exact equal to the digital oracle
(`bnn.folded_forward_exact` hidden layers + `ensemble.votes_fused` head);
tests/test_pipeline.py asserts this across bank configurations.

Silicon mode: `compile_pipeline(folded, cfg, noise=SILICON)` threads the
unified device physics (`core/physics.SearchPhysics`) through the SAME
fused program — per-pass effective thresholds are sampled as [P, B, C]
float arrays (sigma_hd per row; sigma_vref / sigma_tjitter pass-global
through the Table-I knob schedule; temp_drift_hd systematic) and only the
head compare changes, so the HD-once/compare-33x amortization survives
noise.  The spec's `noise` axis selects the draw shape:

  "batch"       — one realization for the whole batch (`key=`); row
                  realizations depend on batch composition and bucket
                  padding (a measurement-style draw).
  "per_request" — one batch_shape=() draw per row from `keys[i]`;
                  results are invariant to how a serving loop coalesces
                  requests (the serve determinism contract).

`mc_samples=S` vmaps S independent threshold realizations over ONE
Hamming-distance computation; `cumulative=True` exposes the per-pass
cumulative votes [P, B, C] that noisy Fig.-5-style truncated sweeps need
(`ensemble.sweep_from_votes` is noiseless-only — see its docstring).
`InferenceSpec(noise="off", cumulative=True)` is the exact noiseless
staircase, valid on ANY pipeline — the explicit form of what `cum_votes`
used to do by silently substituting `PRNGKey(0)`.  With
`noise=NOISELESS` every noisy spec is bit-identical to the noiseless
oracle (tested).

Two fused implementations, selected by `impl` (default: by backend):

  pallas — kernels/fused_mlp.py: one kernel launch per batch block,
           hidden activations resident in VMEM (the TPU deployment path;
           runs under interpret mode elsewhere, for semantics only).  The
           noisy path feeds the kernel a precomputed [B, C, P]
           threshold-sample operand — randomness never enters the kernel.
  xla    — the same packed-domain math as a single jitted XLA program:
           activations stay uint32-packed between layers and the whole
           net fuses into one executable (the portable fast path — on
           CPU this is what beats the layer-by-layer unpacked flow; see
           benchmarks/e2e_throughput.py).  The noisy path broadcasts the
           sampled [P, B, C] thresholds against the one HD computation.

Monte-Carlo, cumulative, and per-request specs always use the XLA-twin
math (per-pass/per-sample outputs do not fit the kernel's single [B, C]
result block); the twins are bit-exact equal so this is a pure
scheduling choice.

Convolutional graphs: `folded` may start with a prefix of
`convnet.FoldedConvLayer` (a deployed end-to-end-binary CNN, e.g.
`convnet.fold_cnn` output).  The pipeline then takes RAW [0,1] pixels
[B, side*side]: the binary input layer (`image_encoding`, thermometer by
default) and the channel packing run inside the jitted `_pack_fn`, the
conv stack executes in the packed domain (`kernels/fused_conv.py` on the
pallas path, the same shared math as one XLA program otherwise), and the
flatten feeds the FC stage — so every spec works identically for conv
and MLP deployments.  Bit-exactness bar: the unpacked oracle
`kernels.ref.conv_votes_ref` (tests/test_conv.py).

Batch-size bucketing: inputs are zero-padded up to the next bucket
(powers of two, floor `min_bucket`) so a serving loop with ragged batch
sizes compiles O(log B) program variants instead of one per size.

Persistable deployments (`repro.deploy.Deployment`) bundle the folded
layers + encoding + configs this function takes, and rebuild the same
pipeline from disk — see deploy.py.
"""

from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize
from repro.core.bnn import FoldedLayer
from repro.core.convnet import FoldedConvLayer
from repro.core.device_model import NoiseModel
from repro.core.ensemble import CAMEnsembleHead, EnsembleConfig, build_head
from repro.core.physics import SearchPhysics
from repro.kernels import fused_conv, fused_mlp
from repro.spec import InferenceSpec, legacy_entry_spec


def next_bucket(n: int, min_bucket: int = 64,
                max_bucket: Optional[int] = None) -> int:
    """Smallest power-of-two bucket >= n (floored at min_bucket).

    n == 0 is rejected (an empty batch has no bucket — dispatching it
    would burn a full min_bucket of padded compute for zero results), as
    is exceeding the explicit `max_bucket` cap: a serving loop sets the
    cap to its max batch so the compiled-variant set is closed (warmup
    covers every bucket) and an oversized dispatch fails loudly instead
    of silently compiling a new program variant mid-traffic.
    """
    if n <= 0:
        raise ValueError(f"batch size must be >= 1, got {n}")
    b = min_bucket
    while b < n:
        b *= 2
    if max_bucket is not None and b > max_bucket:
        raise ValueError(
            f"batch {n} needs bucket {b} > max_bucket {max_bucket}; "
            "split the batch or recompile with a larger cap"
        )
    return b


def bucket_grid(max_batch: int, min_bucket: int = 64) -> tuple[int, ...]:
    """Every bucket a batch in 1..max_batch can land on (ascending)."""
    out = [min_bucket]
    while out[-1] < max_batch:
        out.append(out[-1] * 2)
    return tuple(out)


def _head_hd_xla(x_packed, layer_ws, layer_cs, layer_n_bits, head_rows,
                 bias_cells: int):
    """Packed-domain fused forward up to the head Hamming distances.

    Same math as the Pallas kernel: XNOR-popcount matvec + C + sign +
    repack per hidden layer, then HD of the (bias-appended) head query
    against every class row.  Returns [B, C] int32 — the one quantity
    every vote path (noiseless, noisy, Monte-Carlo, cumulative) compares
    thresholds against.
    """
    q = x_packed
    n_layers = len(layer_ws)
    for i, (w, c, n_bits) in enumerate(zip(layer_ws, layer_cs, layer_n_bits)):
        hd = binarize.hamming_packed(q[:, None, :], w)
        y = (n_bits - 2 * hd) + c[None, :]
        bits = (y >= 0).astype(jnp.uint8)
        if i + 1 == n_layers:  # head query: append bias drive bits
            ones = jnp.ones((bits.shape[0], bias_cells), jnp.uint8)
            bits = jnp.concatenate([bits, ones], axis=-1)
        q = binarize.pack_bits(bits)
        # align packed width with the next operand's (zero pad words)
        kw_next = (head_rows if i + 1 == n_layers else layer_ws[i + 1]).shape[1]
        if q.shape[1] < kw_next:
            q = jnp.pad(q, ((0, 0), (0, kw_next - q.shape[1])))
    return binarize.hamming_packed(q[:, None, :], head_rows)


_LEGACY_WARNED: set = set()


def _warn_legacy(name: str) -> None:
    """One DeprecationWarning per legacy entry point per process."""
    if name in _LEGACY_WARNED:
        return
    _LEGACY_WARNED.add(name)
    warnings.warn(
        f"CompiledPipeline.{name}() is a deprecated shim over "
        f"run(x, InferenceSpec(...)) — see repro.spec.legacy_entry_spec "
        "and the README migration table; it will be removed next release",
        DeprecationWarning,
        stacklevel=3,
    )


@dataclasses.dataclass
class CompiledPipeline:
    """A jitted end-to-end batch classifier for one deployed BNN.

    The execution surface is `run(x, spec)` / `run_packed(x_packed,
    spec)`: one fused XLA program is compiled and cached per distinct
    `InferenceSpec` (`program(spec)` is the cache), and all bucketing /
    padding / result trimming / PRNG-key validation lives in `run_packed`
    — once, for every spec.  The legacy method family survives as
    deprecated shims that name their spec.
    """

    head: CAMEnsembleHead
    n_in: int
    n_classes: int
    impl: str
    min_bucket: int
    head_only: bool  # no hidden layers: input feeds the CAM head directly
    physics: Optional[SearchPhysics]  # None <=> compiled without noise=
    _program_factory: Callable  # InferenceSpec -> jitted program
    _pack_fn: Callable  # jitted ±1 [B, n_in] -> packed
    max_bucket: Optional[int] = None  # serving cap on the bucket grid
    _programs: dict = dataclasses.field(default_factory=dict, repr=False)

    # ------------------------------------------------------------------
    # the generic compiled-request API
    # ------------------------------------------------------------------
    def program(self, spec: InferenceSpec) -> Callable:
        """The compiled program for `spec` (built and cached on first use).

        Signature depends on the spec's noise axis: `f(x_packed)` for
        "off", `f(x_packed, key)` for "batch", `f(x_packed, keys)` for
        "per_request" — `run_packed` dispatches accordingly.  Callers
        normally never touch this; it exists so warmup and tests can
        assert cache identity.
        """
        prog = self._programs.get(spec)
        if prog is None:
            if spec.needs_physics and self.physics is None:
                raise ValueError(
                    f"{spec.describe()} needs a silicon-mode pipeline: "
                    "recompile with compile_pipeline(..., noise=<NoiseModel>)"
                )
            prog = self._program_factory(spec)
            self._programs[spec] = prog
        return prog

    def run(self, x: jax.Array, spec: InferenceSpec, *,
            key: Optional[jax.Array] = None,
            keys: Optional[jax.Array] = None) -> jax.Array:
        """Execute one declarative inference request on a raw batch.

        x    : [B, n_in] — ±1 activations for MLP pipelines, RAW [0,1]
               pixels for conv pipelines (the binary input encoding and
               channel packing run inside the jitted pack step).
        spec : what to run (`repro.spec.InferenceSpec`).
        key  : batch-level PRNG key — required iff spec.noise=="batch".
        keys : per-request raw uint32 [B, 2] PRNG keys — required iff
               spec.noise=="per_request".

        Returns int32 votes/predictions shaped per the spec (see
        repro/spec.py's shape table), trimmed to the logical batch.
        """
        return self.run_packed(self._pack_input(x), spec, key=key, keys=keys)

    def run_packed(self, x_packed: jax.Array, spec: InferenceSpec, *,
                   key: Optional[jax.Array] = None,
                   keys: Optional[jax.Array] = None) -> jax.Array:
        """`run` for an already-packed input batch [B, Kw0].

        Conv pipelines: Kw0 = side*side*Cw0, the row-flattened channel-
        packed encoded image the jitted pack step emits (`_pack_input`).
        This is the ONE place bucket padding, key-shape validation, and
        result trimming happen, for every spec.
        """
        prog = self.program(spec)  # physics capability check happens here
        x_packed, b = self._bucketed(x_packed)
        if spec.needs_keys:
            if key is not None:
                raise ValueError(
                    f"{spec.describe()} takes per-request keys=, not a "
                    "batch-level key="
                )
            if keys is None:
                raise ValueError(
                    f"{spec.describe()} needs per-request keys= "
                    "([B, 2] raw uint32 PRNG keys)"
                )
            out = prog(x_packed, self._each_keys(keys, b, x_packed.shape[0]))
        elif spec.needs_key:
            if keys is not None:
                raise ValueError(
                    f"{spec.describe()} takes one batch-level key=, not "
                    "per-request keys="
                )
            if key is None:
                raise ValueError(
                    f"{spec.describe()} needs an explicit key= (each call "
                    "is one silicon realization)"
                )
            out = prog(x_packed, key)
        else:
            if key is not None or keys is not None:
                raise ValueError(
                    f'{spec.describe()} is deterministic (noise="off"): '
                    "it accepts neither key= nor keys="
                )
            out = prog(x_packed)
        return self._trim(out, b, spec.batch_axis)

    # ------------------------------------------------------------------
    # shared glue (bucketing / packing / trimming / key shapes)
    # ------------------------------------------------------------------
    def _pack_input(self, x_pm1: jax.Array) -> jax.Array:
        # one jitted dispatch: the eager op-by-op pack costs ~5x the whole
        # fused vote program in host dispatch overhead (serving hot path)
        return self._pack_fn(jnp.asarray(x_pm1))

    def _bucketed(self, x_packed: jax.Array):
        b = x_packed.shape[0]
        bp = next_bucket(b, self.min_bucket, self.max_bucket)
        if bp != b:
            x_packed = jnp.pad(x_packed, ((0, bp - b), (0, 0)))
        return x_packed, b

    @staticmethod
    def _trim(out: jax.Array, b: int, axis: int) -> jax.Array:
        # slicing is an eager XLA op per call — skip it when the batch
        # already fills its bucket (the serving hot path by construction)
        if out.shape[axis] == b:
            return out
        return out[:b] if axis == 0 else out[:, :b]

    def _each_keys(self, keys, b: int, bp: int) -> jax.Array:
        keys = jnp.asarray(keys)
        if keys.ndim != 2 or keys.shape[0] != b:
            raise ValueError(
                f"keys must be [B, key_width] raw uint32 PRNG keys with "
                f"B == batch ({b}), got shape {tuple(keys.shape)} — stack "
                "jax.random.PRNGKey / jax.random.split outputs"
            )
        if bp != b:  # pad rows get (valid) zero keys; results are sliced
            keys = jnp.pad(keys, ((0, bp - b), (0, 0)))
        return keys

    def buckets_for(self, max_batch: int) -> tuple[int, ...]:
        """The bucket grid batches 1..max_batch dispatch into."""
        return bucket_grid(max_batch, self.min_bucket)

    # ------------------------------------------------------------------
    # spec-driven warmup
    # ------------------------------------------------------------------
    #: legacy entry names accepted by warmup(entries=) (deprecated —
    #: pass specs= instead; see repro.spec.legacy_entry_spec)
    WARMUP_ENTRIES = ("votes", "votes_noisy", "votes_each", "votes_mc",
                      "votes_mc_each", "votes_mc_each_sum")

    def default_warmup_specs(
        self, mc_samples: Optional[int] = None
    ) -> tuple[InferenceSpec, ...]:
        """Every spec this pipeline supports out of the box.

        Noiseless pipelines warm the plain vote program; silicon-mode
        pipelines add the batch-draw and per-request programs, plus the
        Monte-Carlo family when `mc_samples` is given.  A serving loop
        should instead pass exactly its dispatch spec(s) — each spec is
        a separate XLA compile per bucket.
        """
        if self.physics is None:
            return (InferenceSpec(),)
        specs = [
            InferenceSpec(),
            InferenceSpec(noise="batch"),
            InferenceSpec(noise="per_request"),
        ]
        if mc_samples:
            specs += [
                InferenceSpec(noise="batch", mc_samples=mc_samples),
                InferenceSpec(noise="per_request", mc_samples=mc_samples),
                InferenceSpec(noise="per_request", mc_samples=mc_samples,
                              reduction="sum"),
            ]
        return tuple(specs)

    def warmup(self, max_batch: int, *,
               specs: Optional[Sequence[InferenceSpec]] = None,
               key: Optional[jax.Array] = None,
               mc_samples: Optional[int] = None, device=None,
               entries: Optional[Sequence[str]] = None
               ) -> dict[tuple[InferenceSpec, int], float]:
        """Precompile every (spec, bucket) program a serving loop needs.

        Runs one dummy batch per (spec, bucket) pair and blocks until
        ready, so first-request compile latency never shows up in served
        percentiles.

        specs   : the request specs to warm; default
            `default_warmup_specs(mc_samples)`.  A serving loop passes
            exactly its dispatch spec(s) — startup time is
            specs x buckets x devices XLA compiles.
        entries : DEPRECATED legacy entry names (translated through
            `repro.spec.legacy_entry_spec`); mutually exclusive with
            specs.
        device  : commits the dummy operands — a device for round-robin
            fan-out, or a `jax.sharding.Sharding` for SPMD fan-out (jit
            caches key on input sharding, so warming with a different
            placement than dispatch would never hit).  Scalar keys are
            replicated when a sharding is given (a [2] key cannot take a
            batch-axis shard).

        Returns {(spec, bucket): seconds} — per-program attribution, so
        serving startup can report exactly where compile time went;
        dominated by compile time on first call, ~free when the program
        cache already holds the (spec, bucket) variant.
        """
        if entries is not None:
            if specs is not None:
                raise ValueError("pass specs= or legacy entries=, not both")
            _warn_legacy("warmup(entries=)")
            unknown = set(entries) - set(self.WARMUP_ENTRIES)
            if unknown:
                raise ValueError(f"unknown warmup entries {sorted(unknown)}")
            specs = tuple(
                legacy_entry_spec(
                    e, mc_samples if e.startswith("votes_mc") else None
                )
                for e in entries
            )
        if specs is None:
            specs = self.default_warmup_specs(mc_samples)
        for spec in specs:  # capability check before any compile work
            if spec.needs_physics and self.physics is None:
                raise ValueError(
                    f"warmup of {spec.describe()} needs a silicon-mode "
                    "pipeline: recompile with compile_pipeline(..., "
                    "noise=<NoiseModel>)"
                )

        replicated = None
        if isinstance(device, jax.sharding.NamedSharding):
            from jax.sharding import PartitionSpec

            replicated = jax.sharding.NamedSharding(device.mesh,
                                                    PartitionSpec())
        times: dict[tuple[InferenceSpec, int], float] = {}
        for b in self.buckets_for(max_batch):
            x = jnp.ones((b, self.n_in), jnp.float32)
            k = key if key is not None else jax.random.PRNGKey(0)
            ks = jax.random.split(k, b)
            if device is not None:
                x = jax.device_put(x, device)
                k = jax.device_put(k, replicated or device)
                ks = jax.device_put(ks, device)  # batch-sharded like x
            for spec in specs:
                t0 = time.perf_counter()
                jax.block_until_ready(self.run(
                    x, spec,
                    key=k if spec.needs_key else None,
                    keys=ks if spec.needs_keys else None,
                ))
                times[(spec, b)] = time.perf_counter() - t0
        return times

    # ------------------------------------------------------------------
    # DEPRECATED legacy entry points — thin shims over run()
    # ------------------------------------------------------------------
    def votes(self, x_pm1: jax.Array, key: Optional[jax.Array] = None):
        """DEPRECATED shim: `run(x, InferenceSpec())`, or with `key` one
        batch-level silicon draw (`InferenceSpec(noise="batch")`).

        Input domain: ±1 activations for MLP pipelines; RAW [0,1] pixels
        for conv pipelines (n_in = image_side**2 — the binary input
        encoding and channel packing run inside the jitted pack step).
        With the NOISELESS model the keyed path is bit-identical to the
        noiseless one.
        """
        _warn_legacy("votes")
        if key is None:
            return self.run(x_pm1, InferenceSpec())
        return self.run(x_pm1, InferenceSpec(noise="batch"), key=key)

    def votes_packed(self, x_packed: jax.Array,
                     key: Optional[jax.Array] = None) -> jax.Array:
        """DEPRECATED shim: `run_packed` with the `votes` specs."""
        _warn_legacy("votes_packed")
        if key is None:
            return self.run_packed(x_packed, InferenceSpec())
        return self.run_packed(x_packed, InferenceSpec(noise="batch"),
                               key=key)

    def votes_mc(self, x_pm1: jax.Array, key: jax.Array,
                 n_samples: int) -> jax.Array:
        """DEPRECATED shim: `InferenceSpec(noise="batch", mc_samples=S)`
        -> [S, B, C] Monte-Carlo silicon votes (HD computed ONCE)."""
        _warn_legacy("votes_mc")
        return self.run(
            x_pm1,
            InferenceSpec(noise="batch", mc_samples=int(n_samples)),
            key=key,
        )

    def votes_each(self, x_pm1: jax.Array, keys: jax.Array) -> jax.Array:
        """DEPRECATED shim: `InferenceSpec(noise="per_request")` — one
        batch_shape=() draw per row; invariant to batch composition (the
        serving determinism contract; see repro/spec.py)."""
        _warn_legacy("votes_each")
        return self.run(x_pm1, InferenceSpec(noise="per_request"),
                        keys=keys)

    def votes_mc_each(self, x_pm1: jax.Array, keys: jax.Array,
                      n_samples: int) -> jax.Array:
        """DEPRECATED shim: `InferenceSpec(noise="per_request",
        mc_samples=S)` -> [S, B, C]; sample s of request i is drawn from
        split(keys[i], S)[s], so results are batching-invariant."""
        _warn_legacy("votes_mc_each")
        return self.run(
            x_pm1,
            InferenceSpec(noise="per_request", mc_samples=int(n_samples)),
            keys=keys,
        )

    def votes_mc_each_sum(self, x_pm1: jax.Array, keys: jax.Array,
                          n_samples: int) -> jax.Array:
        """DEPRECATED shim: the per-request MC spec with
        reduction="sum" — the MC serving aggregate, reduction fused into
        the compiled program."""
        _warn_legacy("votes_mc_each_sum")
        return self.run(
            x_pm1,
            InferenceSpec(noise="per_request", mc_samples=int(n_samples),
                          reduction="sum"),
            keys=keys,
        )

    def predict_each(self, x_pm1: jax.Array, keys: jax.Array) -> jax.Array:
        """DEPRECATED shim: `InferenceSpec(noise="per_request",
        reduction="argmax")` — per-request-key Algorithm 1 prediction."""
        _warn_legacy("predict_each")
        return self.run(
            x_pm1,
            InferenceSpec(noise="per_request", reduction="argmax"),
            keys=keys,
        )

    def cum_votes(self, x_pm1: jax.Array,
                  key: Optional[jax.Array] = None) -> jax.Array:
        """DEPRECATED shim: per-pass cumulative votes [P, B, C].

        key given  -> `InferenceSpec(noise="batch", cumulative=True)`:
            one silicon realization's staircase (the silicon-conditioned
            replacement for `ensemble.sweep_from_votes`, which is valid
            ONLY noiseless).
        key=None   -> `InferenceSpec(cumulative=True)`: the exact
            noiseless staircase (== sweep_from_votes of the fused
            total).  This used to silently substitute `PRNGKey(0)`; it
            is now an explicit deterministic spec, valid on any
            pipeline.  A noise-compiled pipeline must still be given a
            key explicitly — each call is one silicon realization.
        """
        _warn_legacy("cum_votes")
        if key is None:
            if self.physics is not None and not self.physics.is_noiseless:
                raise ValueError(
                    "cum_votes on a noise-compiled pipeline needs an "
                    "explicit key (each call is one silicon realization); "
                    "for the deterministic staircase run the explicit "
                    'spec InferenceSpec(noise="off", cumulative=True) on '
                    "a noiseless pipeline"
                )
            return self.run(x_pm1, InferenceSpec(cumulative=True))
        return self.run(x_pm1, InferenceSpec(noise="batch", cumulative=True),
                        key=key)

    def predict(self, x_pm1: jax.Array,
                key: Optional[jax.Array] = None) -> jax.Array:
        """DEPRECATED shim: `InferenceSpec(reduction="argmax")` —
        Algorithm 1 prediction (per-class majority vote -> argmax)."""
        _warn_legacy("predict")
        if key is None:
            return self.run(x_pm1, InferenceSpec(reduction="argmax"))
        return self.run(
            x_pm1, InferenceSpec(noise="batch", reduction="argmax"), key=key
        )

    def __call__(self, x_pm1: jax.Array,
                 key: Optional[jax.Array] = None) -> jax.Array:
        """Sugar for the deprecated `predict` shim."""
        return self.predict(x_pm1, key)


def compile_pipeline(
    folded: Sequence,
    ens_cfg: EnsembleConfig | None = None,
    *,
    impl: str | None = None,
    bq: int | None = None,
    chunk: int = 4,
    min_bucket: int = 64,
    max_bucket: int | None = None,
    interpret: bool | None = None,
    noise: NoiseModel | None = None,
    params=None,
    donate: bool = False,
    image_side: int | None = None,
    image_encoding: binarize.InputEncoding | None = None,
) -> CompiledPipeline:
    """Compile a folded BNN + ensemble head into a fused batch classifier.

    folded  : `bnn.fold` output — hidden layers + the output layer (last).
              May start with a prefix of `convnet.FoldedConvLayer`
              (`convnet.fold_cnn` output): the pipeline then runs the
              end-to-end-binary CNN workload and its input domain becomes
              RAW [0,1] pixels [B, image_side**2] (the binary input
              encoding runs inside the jitted pack step).
    ens_cfg : Algorithm-1 config (thresholds / bias cells); default paper's.
    impl    : "pallas" | "xla" | None (auto: pallas on TPU, xla elsewhere —
              the Pallas kernel only *executes* off-TPU in interpret mode,
              which is for semantics tests, not speed).
    bq      : Pallas batch-block size; default 256 for MLP graphs, 64
              for conv graphs (the conv kernel's per-tap XOR temporary
              scales the VMEM working set ~4x — DESIGN.md §10 derives
              both budgets).
    noise   : optional NoiseModel — enables the silicon-mode specs
              (noise="batch"/"per_request", Monte-Carlo, noisy
              cumulative) by building a SearchPhysics bundle from the
              head's threshold schedule; `params` optionally overrides
              the AnalogParams.  noise=None keeps the pipeline
              noiseless-only (no knob-schedule work at compile time).
    max_bucket : optional cap on the batch-bucket grid (see next_bucket);
              serving loops set it to their max batch so warmup() closes
              the compiled-variant set.
    donate  : donate the packed input buffer to the compiled programs
              (donate_argnums) — the packing step produces a fresh
              buffer per call, so a serving loop can hand it to the
              program and save an allocation on TPU/GPU.  No effect on
              results; backends that can't reuse the buffer (CPU) just
              ignore the donation.  Off by default because `run_packed`
              is public API and donation invalidates the caller's array.
    image_side : REQUIRED for conv graphs — square input image side
              (`n_in = image_side**2` raw pixels).  Rejected for pure
              MLP graphs.
    image_encoding : the binary input layer for conv graphs
              (`binarize.InputEncoding`); its width must equal the first
              conv layer's c_in.  Default: thermometer of that width.

    The returned pipeline compiles lazily: `run(x, spec)` builds one
    fused program per distinct `InferenceSpec` on first use (warmup()
    precompiles a chosen set).  `repro.deploy.deploy(...)` wraps this
    call in a persistable `Deployment` artifact.
    """
    ens_cfg = ens_cfg or EnsembleConfig()
    if len(folded) < 1:
        raise ValueError("need at least the output layer")
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown pipeline impl {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    rest = list(folded)
    conv_layers: list[FoldedConvLayer] = []
    while rest and isinstance(rest[0], FoldedConvLayer):
        conv_layers.append(rest.pop(0))
    if any(isinstance(l, FoldedConvLayer) for l in rest):
        raise ValueError("conv layers must form a prefix of `folded`")
    if not rest:
        raise ValueError("need an output FC layer after the conv stack")
    if conv_layers and image_side is None:
        raise ValueError("conv graphs need image_side=")
    if not conv_layers and (image_side is not None
                            or image_encoding is not None):
        raise ValueError("image_side/image_encoding are conv-only options")
    if bq is None:
        # the conv kernel's [bq, O, O, c_out, Cw] per-tap temporary is
        # ~4x the MLP kernel's working set per batch row (DESIGN.md §10)
        bq = 64 if conv_layers else 256

    hidden, out_layer = list(rest[:-1]), rest[-1]
    head = build_head(out_layer, ens_cfg)
    n_classes = head.n_classes

    layer_ws = tuple(
        binarize.pack_bits(jnp.asarray((l.weights_pm1 > 0).astype(np.uint8)))
        for l in hidden
    )
    layer_cs = tuple(jnp.asarray(l.c, jnp.int32) for l in hidden)
    layer_n_bits = tuple(int(l.n_in) for l in hidden)
    head_rows = head.cam.rows_packed
    thresholds = head.thresholds

    conv_metas = conv_ws = conv_cs = None
    head_direct = False
    if conv_layers:
        enc = image_encoding or binarize.InputEncoding(
            "thermometer", conv_layers[0].c_in
        )
        if enc.width != conv_layers[0].c_in:
            raise ValueError(
                f"encoding width {enc.width} != first conv c_in "
                f"{conv_layers[0].c_in}"
            )
        conv_metas = fused_conv.conv_metas_for(conv_layers, image_side)
        conv_ws = tuple(fused_conv.pack_conv_rows(l) for l in conv_layers)
        conv_cs = tuple(jnp.asarray(l.c, jnp.int32) for l in conv_layers)
        mf = conv_metas[-1]
        n_pos, c_f = mf.out_side * mf.out_side, mf.c_out
        first_fc = hidden[0] if hidden else out_layer
        if int(first_fc.n_in) != n_pos * c_f:
            raise ValueError(
                f"first FC layer n_in {first_fc.n_in} != flattened conv "
                f"features {n_pos}*{c_f}"
            )
        head_direct = not hidden
        if head_direct and c_f % 32:
            raise ValueError(
                "conv -> head-direct needs last conv c_out % 32 == 0 "
                f"(word-aligned flatten), got {c_f}"
            )
        if hidden:
            # the flatten keeps per-position word padding — repack the
            # first FC layer's rows with the matching alignment
            layer_ws = (
                fused_conv.pack_fc_rows_positionwise(
                    (hidden[0].weights_pm1 > 0).astype(np.uint8),
                    n_pos, c_f,
                ),
            ) + layer_ws[1:]
        side, cw0 = image_side, conv_metas[0].cw_in

        def _pack_conv(x01):
            img = jnp.asarray(x01).reshape(-1, side, side)
            words = binarize.pack_bits(enc.encode_bits(img))
            return words.reshape(words.shape[0], side * side * cw0)

        pack_fn = jax.jit(_pack_conv)
    elif hidden:
        pack_fn = jax.jit(binarize.pack_pm1)
    else:
        from repro.core.cam import query_with_bias

        pack_fn = jax.jit(
            functools.partial(query_with_bias, bias_cells=head.bias_cells)
        )

    phys = None
    if noise is not None:
        phys = SearchPhysics.for_head(head, noise, params)

    # donation-friendly programs: the packed input is the only per-call
    # buffer worth donating (weights live in the closures)
    donate_kw = {"donate_argnums": (0,)} if donate else {}

    # chunk-padded operands for the XLA-twin math (also backs the
    # Monte-Carlo / cumulative / per-request paths of a pallas pipeline)
    ws = tuple(fused_mlp._pad_words(w, chunk) for w in layer_ws)
    hr = fused_mlp._pad_words(head_rows, chunk)

    if conv_layers:
        bias_words = (fused_conv.bias_drive_words(head.bias_cells)
                      if head_direct else None)

        def _front(x_packed):
            # [B, S*S*Cw0] -> conv stack -> flattened packed FC query
            x4 = x_packed.reshape(-1, image_side, image_side, cw0)
            return fused_conv.conv_stage_packed(
                x4, conv_ws, conv_cs, conv_metas, bias_words
            )
    else:
        def _front(x_packed):
            return x_packed

    def _hd_xla(x_packed):
        q = _front(x_packed)
        kw0 = (ws[0] if ws else hr).shape[1]
        if q.shape[1] < kw0:
            q = jnp.pad(q, ((0, 0), (0, kw0 - q.shape[1])))
        return _head_hd_xla(
            q, ws, layer_cs, layer_n_bits, hr, head.bias_cells
        )

    # the two kernel-eligible vote producers (single [B, C] result block)
    if impl == "pallas" and conv_layers:
        def _kernel_votes(x_packed, thr_samples=None):
            return fused_conv.fused_conv_votes(
                x_packed.reshape(-1, image_side, image_side, cw0),
                conv_ws, conv_cs, conv_metas,
                layer_ws, layer_cs, layer_n_bits, head_rows, thresholds,
                bias_cells=head.bias_cells, bq=bq, chunk=chunk,
                interpret=interpret, head_direct=head_direct,
                thr_samples=thr_samples,
            )
    elif impl == "pallas":
        def _kernel_votes(x_packed, thr_samples=None):
            return fused_mlp.fused_mlp_votes(
                x_packed, layer_ws, layer_cs, layer_n_bits,
                head_rows, thresholds,
                bias_cells=head.bias_cells, bq=bq, chunk=chunk,
                interpret=interpret, thr_samples=thr_samples,
            )
    else:
        _kernel_votes = None

    def _votes_off(x_packed):
        if _kernel_votes is not None:
            return _kernel_votes(x_packed)
        hd = _hd_xla(x_packed)
        return (hd[:, :, None] <= thresholds[None, None, :]).astype(
            jnp.int32
        ).sum(-1)

    def _votes_batch(x_packed, key):
        # one batch-shaped draw: sampled [P, B, C] thresholds against the
        # single HD computation
        if _kernel_votes is not None:
            t = phys.sample(
                key, batch_shape=(x_packed.shape[0],), n_rows=n_classes
            )  # [P, B, C]
            return _kernel_votes(
                x_packed, thr_samples=jnp.moveaxis(t, 0, -1)  # [B, C, P]
            )
        hd = _hd_xla(x_packed).astype(jnp.float32)  # [B, C]
        t = phys.sample(key, batch_shape=(hd.shape[0],), n_rows=n_classes)
        return (hd[None] <= t).astype(jnp.int32).sum(0)

    # per-request draw: batch_shape=() per row — each row's realization
    # depends only on (x_i, keys_i), never on batch composition or bucket
    # padding (the serve determinism contract)
    def _votes_one(hd_i, k):
        t = phys.sample(k, (), n_classes)  # [P, C]
        return (hd_i[None] <= t).astype(jnp.int32).sum(0)

    def make_program(spec: InferenceSpec) -> Callable:
        """Build the fused program for one spec (jitted; signature per
        the spec's noise axis — see CompiledPipeline.program)."""
        mc = spec.mc_samples

        if spec.cumulative:
            if spec.noise == "off":
                def fn(x_packed):
                    # the exact staircase: per-pass match indicators of
                    # the deterministic compare, cumsum'd over passes
                    hd = _hd_xla(x_packed)
                    per = (hd[None, :, :] <= thresholds[:, None, None])
                    return jnp.cumsum(per.astype(jnp.int32), axis=0)
            else:  # "batch"
                def fn(x_packed, key):
                    hd = _hd_xla(x_packed).astype(jnp.float32)
                    t = phys.sample(key, (hd.shape[0],), n_classes)
                    return jnp.cumsum((hd[None] <= t).astype(jnp.int32),
                                      axis=0)
        elif spec.noise == "off":
            fn = _votes_off
        elif spec.noise == "batch":
            if mc is None:
                fn = _votes_batch
            else:
                def fn(x_packed, key):
                    hd = _hd_xla(x_packed).astype(jnp.float32)  # ONCE

                    def one(k):
                        t = phys.sample(k, (hd.shape[0],), n_classes)
                        return (hd[None] <= t).astype(jnp.int32).sum(0)

                    out = jax.vmap(one)(jax.random.split(key, mc))
                    return out.sum(0) if spec.reduction == "sum" else out
        else:  # "per_request"
            if mc is None:
                def fn(x_packed, keys):
                    hd = _hd_xla(x_packed).astype(jnp.float32)  # [B, C]
                    return jax.vmap(_votes_one)(hd, keys)
            elif spec.reduction == "sum":
                def fn(x_packed, keys):
                    hd = _hd_xla(x_packed).astype(jnp.float32)

                    def per_req(hd_i, k):
                        return jax.vmap(lambda ks: _votes_one(hd_i, ks))(
                            jax.random.split(k, mc)
                        ).sum(0)  # [C] — reduction fused into the program

                    return jax.vmap(per_req)(hd, keys)  # [B, C]
            else:
                def fn(x_packed, keys):
                    hd = _hd_xla(x_packed).astype(jnp.float32)  # ONCE

                    def per_req(hd_i, k):
                        return jax.vmap(lambda ks: _votes_one(hd_i, ks))(
                            jax.random.split(k, mc)
                        )  # [S, C]

                    return jnp.moveaxis(
                        jax.vmap(per_req)(hd, keys), 1, 0
                    )  # [S, B, C] (votes_mc layout)

        if spec.reduction == "argmax":
            base = fn  # single-realization vote producer, [B, C]
            if spec.noise == "off":
                def fn(x_packed):
                    return jnp.argmax(base(x_packed), axis=-1)
            else:
                def fn(x_packed, rng):
                    return jnp.argmax(base(x_packed, rng), axis=-1)

        return jax.jit(fn, **donate_kw)

    if conv_layers:
        n_in = int(image_side) ** 2  # raw [0,1] pixels in, encode inside
    elif hidden:
        n_in = int(hidden[0].n_in)
    else:
        n_in = int(out_layer.n_in)
    return CompiledPipeline(
        head=head,
        n_in=n_in,
        n_classes=n_classes,
        impl=impl,
        min_bucket=min_bucket,
        head_only=not hidden,
        physics=phys,
        _program_factory=make_program,
        _pack_fn=pack_fn,
        max_bucket=max_bucket,
    )

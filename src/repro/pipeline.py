"""End-to-end deployed-BNN inference pipeline (packed domain, fused).

`compile_pipeline(folded, ens_cfg)` turns a folded binary MLP (list of
`bnn.FoldedLayer`) plus an Algorithm-1 ensemble config into a jitted
batch classifier:

    pipe = compile_pipeline(folded, EnsembleConfig())
    votes = pipe.votes(x_pm1)     # [B, n_classes] int32 vote counts
    pred  = pipe.predict(x_pm1)   # [B] int32 argmax classes

Semantics are bit-exact equal to the digital oracle
(`bnn.folded_forward_exact` hidden layers + `ensemble.votes_fused` head);
tests/test_pipeline.py asserts this across bank configurations.

Two fused implementations, selected by `impl` (default: by backend):

  pallas — kernels/fused_mlp.py: one kernel launch per batch block,
           hidden activations resident in VMEM (the TPU deployment path;
           runs under interpret mode elsewhere, for semantics only).
  xla    — the same packed-domain math as a single jitted XLA program:
           activations stay uint32-packed between layers and the whole
           net fuses into one executable (the portable fast path — on
           CPU this is what beats the layer-by-layer unpacked flow; see
           benchmarks/e2e_throughput.py).

Batch-size bucketing: inputs are zero-padded up to the next bucket
(powers of two, floor `min_bucket`) so a serving loop with ragged batch
sizes compiles O(log B) program variants instead of one per size.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize
from repro.core.bnn import FoldedLayer
from repro.core.ensemble import CAMEnsembleHead, EnsembleConfig, build_head
from repro.kernels import fused_mlp


def next_bucket(n: int, min_bucket: int = 64) -> int:
    """Smallest power-of-two bucket >= n (floored at min_bucket)."""
    b = min_bucket
    while b < n:
        b *= 2
    return b


def _votes_xla(x_packed, layer_ws, layer_cs, layer_n_bits, head_rows,
               thresholds, bias_cells: int):
    """Packed-domain fused forward as straight-line jnp (one XLA program).

    Same math as the Pallas kernel: XNOR-popcount matvec + C + sign +
    repack per hidden layer, multi-threshold vote at the head.  Bit-exact
    equal to `fused_mlp.fused_mlp_votes` (integer arithmetic throughout).
    """
    q = x_packed
    n_layers = len(layer_ws)
    for i, (w, c, n_bits) in enumerate(zip(layer_ws, layer_cs, layer_n_bits)):
        hd = binarize.hamming_packed(q[:, None, :], w)
        y = (n_bits - 2 * hd) + c[None, :]
        bits = (y >= 0).astype(jnp.uint8)
        if i + 1 == n_layers:  # head query: append bias drive bits
            ones = jnp.ones((bits.shape[0], bias_cells), jnp.uint8)
            bits = jnp.concatenate([bits, ones], axis=-1)
        q = binarize.pack_bits(bits)
        # align packed width with the next operand's (zero pad words)
        kw_next = (head_rows if i + 1 == n_layers else layer_ws[i + 1]).shape[1]
        if q.shape[1] < kw_next:
            q = jnp.pad(q, ((0, 0), (0, kw_next - q.shape[1])))
    hd = binarize.hamming_packed(q[:, None, :], head_rows)
    return (hd[:, :, None] <= thresholds[None, None, :]).astype(
        jnp.int32
    ).sum(-1)


@dataclasses.dataclass
class CompiledPipeline:
    """A jitted end-to-end batch classifier for one deployed BNN."""

    head: CAMEnsembleHead
    n_in: int
    n_classes: int
    impl: str
    min_bucket: int
    head_only: bool  # no hidden layers: input feeds the CAM head directly
    _votes_packed: callable  # [Bp, Kw0] uint32 -> [Bp, C] int32 (jitted)

    def votes(self, x_pm1: jax.Array) -> jax.Array:
        """Vote counts for a ±1 input batch [B, n_in] -> [B, C] int32."""
        x_pm1 = jnp.asarray(x_pm1)
        if self.head_only:
            from repro.core.cam import query_with_bias

            x_packed = query_with_bias(x_pm1, self.head.bias_cells)
        else:
            x_packed = binarize.pack_pm1(x_pm1)
        return self.votes_packed(x_packed)

    def votes_packed(self, x_packed: jax.Array) -> jax.Array:
        """Vote counts for an already-packed input batch [B, Kw0]."""
        b = x_packed.shape[0]
        bp = next_bucket(b, self.min_bucket)
        if bp != b:
            x_packed = jnp.pad(x_packed, ((0, bp - b), (0, 0)))
        return self._votes_packed(x_packed)[:b]

    def predict(self, x_pm1: jax.Array) -> jax.Array:
        """Algorithm 1 prediction: per-class majority vote -> argmax."""
        return jnp.argmax(self.votes(x_pm1), axis=-1)

    def __call__(self, x_pm1: jax.Array) -> jax.Array:
        return self.predict(x_pm1)


def compile_pipeline(
    folded: Sequence[FoldedLayer],
    ens_cfg: EnsembleConfig | None = None,
    *,
    impl: str | None = None,
    bq: int = 256,
    chunk: int = 4,
    min_bucket: int = 64,
    interpret: bool | None = None,
) -> CompiledPipeline:
    """Compile a folded BNN + ensemble head into a fused batch classifier.

    folded  : `bnn.fold` output — hidden layers + the output layer (last).
    ens_cfg : Algorithm-1 config (thresholds / bias cells); default paper's.
    impl    : "pallas" | "xla" | None (auto: pallas on TPU, xla elsewhere —
              the Pallas kernel only *executes* off-TPU in interpret mode,
              which is for semantics tests, not speed).
    """
    ens_cfg = ens_cfg or EnsembleConfig()
    if len(folded) < 1:
        raise ValueError("need at least the output layer")
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown pipeline impl {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    hidden, out_layer = list(folded[:-1]), folded[-1]
    head = build_head(out_layer, ens_cfg)

    layer_ws = tuple(
        binarize.pack_bits(jnp.asarray((l.weights_pm1 > 0).astype(np.uint8)))
        for l in hidden
    )
    layer_cs = tuple(jnp.asarray(l.c, jnp.int32) for l in hidden)
    layer_n_bits = tuple(int(l.n_in) for l in hidden)
    head_rows = head.cam.rows_packed
    thresholds = head.thresholds

    if impl == "pallas":
        def votes_packed_fn(x_packed):
            return fused_mlp.fused_mlp_votes(
                x_packed, layer_ws, layer_cs, layer_n_bits,
                head_rows, thresholds,
                bias_cells=head.bias_cells, bq=bq, chunk=chunk,
                interpret=interpret,
            )
    else:
        # zero-pad every packed operand pair to a common word width once,
        # at compile time, so the jitted program has no ragged shapes
        ws = [fused_mlp._pad_words(w, chunk) for w in layer_ws]
        hr = fused_mlp._pad_words(head_rows, chunk)

        @jax.jit
        def votes_packed_fn(x_packed):
            kw0 = (ws[0] if ws else hr).shape[1]
            if x_packed.shape[1] < kw0:
                x_packed = jnp.pad(
                    x_packed, ((0, 0), (0, kw0 - x_packed.shape[1]))
                )
            return _votes_xla(
                x_packed, ws, layer_cs, layer_n_bits, hr, thresholds,
                head.bias_cells,
            )

    return CompiledPipeline(
        head=head,
        n_in=int(hidden[0].n_in) if hidden else int(out_layer.n_in),
        n_classes=head.n_classes,
        impl=impl,
        min_bucket=min_bucket,
        head_only=not hidden,
        _votes_packed=votes_packed_fn,
    )

"""Elastic scaling: reshard a training state onto a different mesh.

Scenario: a pod (or host) is lost mid-run; the scheduler hands back a
smaller (or later, larger) device set.  The supervisor rebuilds the mesh,
recomputes shardings from the SAME logical rules, and either (a) restores
the latest checkpoint against the new shardings (cold path, always works)
or (b) reshards the live state with device_put (warm path, same process).

Batch elasticity: the global batch is kept constant by rescaling the
gradient-accumulation factor (microbatches) to the new data-parallel
width — training math is unchanged across rescales (tests assert the loss
trajectory is identical across a mid-run 2->1 pod rescale, modulo bf16).
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.sharding import AxisRules
from repro.sharding.rules import sanitize_spec


def state_shardings(cfg: ModelConfig, mesh: Mesh, rules: AxisRules,
                    state_template) -> dict:
    """NamedSharding pytree for a {"params", "opt"} train state."""
    rules = rules.resolve(mesh)
    p_ps = M.param_pspecs(cfg, rules)

    def named(ps_tree, tpl_tree):
        return jax.tree_util.tree_map(
            lambda spec, tpl: NamedSharding(
                mesh, sanitize_spec(spec, tpl.shape, mesh)
            ),
            ps_tree,
            tpl_tree,
            is_leaf=lambda x: not isinstance(x, dict),
        )

    from jax.sharding import PartitionSpec as P

    tpl = state_template
    out = {"params": named(p_ps, tpl["params"])}
    opt = {}
    for k in tpl["opt"]:
        if k == "step":
            opt[k] = NamedSharding(mesh, P())
        else:
            opt[k] = named(p_ps, tpl["opt"][k])
    out["opt"] = opt
    return out


def reshard_state(state, shardings):
    """Warm-path reshard: device_put every leaf to its new sharding."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), state, shardings
    )


def rescale_microbatches(
    global_batch: int, old_dp: int, new_dp: int, old_microbatches: int
) -> int:
    """Keep global batch + per-device microbatch memory constant."""
    per_dev = global_batch // (old_dp * old_microbatches)
    new_mb = max(1, global_batch // (new_dp * per_dev))
    return new_mb

"""Failure injection for fault-tolerance tests.

Wraps a step function so it raises at chosen steps (once each), emulating
device loss / preemption.  Also provides a slow-step wrapper for
straggler-detector tests.
"""

from __future__ import annotations

import time
from typing import Callable, Iterable


class InjectedFailure(RuntimeError):
    pass


def failing_step(step_fn: Callable, fail_at: Iterable[int]) -> Callable:
    remaining = set(fail_at)
    counter = {"step": 0}

    def wrapped(state, batch):
        s = counter["step"]
        counter["step"] += 1
        if s in remaining:
            remaining.discard(s)
            raise InjectedFailure(f"injected failure at step {s}")
        return step_fn(state, batch)

    return wrapped


def slow_step(step_fn: Callable, slow_at: Iterable[int], delay_s: float):
    slow = set(slow_at)
    counter = {"step": 0}

    def wrapped(state, batch):
        s = counter["step"]
        counter["step"] += 1
        if s in slow:
            time.sleep(delay_s)
        return step_fn(state, batch)

    return wrapped

"""Fault-tolerant training supervisor: checkpoint/restart, failure
isolation, straggler monitoring, heartbeats.

At thousand-node scale the supervisor's contract is:
  * every step is RESTARTABLE: state lives in (checkpoint, data cursor),
    and the data pipeline is deterministic in (seed, step) — a restart
    replays the exact failed step;
  * failures are CONTAINED: a step exception (XLA abort, device loss,
    injected fault) triggers restore-from-latest + replay, up to
    max_restarts, with exponential backoff;
  * stragglers are DETECTED: per-step wall times feed an EWMA z-score
    detector; sustained outliers raise a StragglerAlert so the scheduler
    can drain-and-replace the slow host (on real fleets this hooks the
    pod-manager API; here the hook is a callback, exercised by tests);
  * liveness is OBSERVABLE: a heartbeat file is touched every step —
    an external watchdog restarts the whole process when it goes stale.
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Callable, Iterator, Optional

import jax

from repro.checkpoint import AsyncCheckpointer, latest_step, restore


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: Path
    ckpt_every: int = 50
    keep_last: int = 3
    max_restarts: int = 5
    backoff_s: float = 0.1
    heartbeat: Optional[Path] = None
    # straggler detection
    ewma_alpha: float = 0.1
    straggler_z: float = 4.0
    straggler_patience: int = 3


class StragglerMonitor:
    """EWMA mean/variance z-score over step wall times."""

    def __init__(self, alpha: float, z: float, patience: int):
        self.alpha, self.z, self.patience = alpha, z, patience
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.strikes = 0
        self.alerts: list[dict] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True when a straggler alert fires."""
        if self.mean is None:
            self.mean = dt
            return False
        sd = max(self.var**0.5, 1e-6, 0.05 * self.mean)
        zscore = (dt - self.mean) / sd
        fire = False
        if zscore > self.z:
            self.strikes += 1
            if self.strikes >= self.patience:
                self.alerts.append(
                    {"step": step, "dt": dt, "mean": self.mean, "z": zscore}
                )
                self.strikes = 0
                fire = True
            # ROBUST update: outlier samples do not enter the EWMA —
            # otherwise a sustained straggler inflates the variance and
            # masks itself before `patience` strikes accumulate
            return fire
        self.strikes = 0
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return fire


class Supervisor:
    """Runs (step_fn, data_iter_factory) with checkpoint/restart."""

    def __init__(
        self,
        cfg: SupervisorConfig,
        step_fn: Callable,  # (state, batch) -> (state, metrics)
        make_data: Callable[[int], Iterator],  # start_step -> iterator
        state_template,  # pytree of arrays/SDS for elastic restore
        shardings=None,
        on_straggler: Optional[Callable[[dict], None]] = None,
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.make_data = make_data
        self.state_template = state_template
        self.shardings = shardings
        self.on_straggler = on_straggler
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir, cfg.keep_last)
        self.monitor = StragglerMonitor(
            cfg.ewma_alpha, cfg.straggler_z, cfg.straggler_patience
        )
        self.restarts = 0
        self.history: list[dict] = []

    def _restore_or(self, init_state):
        last = latest_step(self.cfg.ckpt_dir)
        if last is None:
            return init_state, 0
        state, step = restore(
            self.cfg.ckpt_dir, last, self.state_template, self.shardings
        )
        return state, step

    def _heartbeat(self, step: int):
        hb = self.cfg.heartbeat
        if hb is not None:
            hb.write_text(json.dumps({"step": step, "time": time.time()}))

    def run(self, init_state, n_steps: int):
        """Train to n_steps total, surviving step failures."""
        state, start = self._restore_or(init_state)
        while start < n_steps:
            data = self.make_data(start)
            try:
                for step in range(start, n_steps):
                    batch = next(data)
                    t0 = time.time()
                    state, metrics = self.step_fn(state, batch)
                    jax.block_until_ready(
                        jax.tree_util.tree_leaves(state)[0]
                    )
                    dt = time.time() - t0
                    self._heartbeat(step)
                    if self.monitor.observe(step, dt) and self.on_straggler:
                        self.on_straggler(self.monitor.alerts[-1])
                    self.history.append(
                        {"step": step, "dt": dt,
                         **{k: float(v) for k, v in metrics.items()}}
                    )
                    if (step + 1) % self.cfg.ckpt_every == 0:
                        self.ckpt.save_async(step + 1, state)
                start = n_steps
            except Exception:  # noqa: BLE001 — containment boundary
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                time.sleep(self.cfg.backoff_s * 2 ** (self.restarts - 1))
                self.ckpt.wait()
                state, start = self._restore_or(init_state)
        self.ckpt.wait()
        return state

"""Fault tolerance: supervisor (checkpoint/restart + straggler monitor),
elastic resharding, failure injection for tests."""

from repro.ft.supervisor import Supervisor, SupervisorConfig, StragglerMonitor  # noqa: F401
from repro.ft.elastic import reshard_state, rescale_microbatches, state_shardings  # noqa: F401
from repro.ft.failures import InjectedFailure, failing_step, slow_step  # noqa: F401

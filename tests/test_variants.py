"""§Perf hillclimb variants: lowering + semantics on the host mesh."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeConfig
from repro.launch import specs
from repro.models import model as M
from repro.sharding import (
    SERVE_SEQCACHE_RULES,
    TRAIN_RULES,
    TRAIN_SP_RULES,
    ZERO1_PARAM_RULES,
    use_rules,
)
from repro.sharding.rules import logical_axis_size
from repro.train import TrainConfig
from repro.train.train_step import train_step

SMALL_TRAIN = ShapeConfig("train_4k", "train", 64, 4)
SMALL_DECODE = ShapeConfig("decode_32k", "decode", 64, 2)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_sp_rules_shard_act_seq():
    assert TRAIN_SP_RULES.spec("act_seq") == jax.sharding.PartitionSpec(
        "model"
    )
    assert TRAIN_RULES.spec("act_seq") == jax.sharding.PartitionSpec(None)


def test_zero1_rules_replicate_params():
    s = ZERO1_PARAM_RULES.spec("p_mlp_d", "p_mlp_f")
    assert s == jax.sharding.PartitionSpec(None, "model")


def test_seqcache_rules():
    s = SERVE_SEQCACHE_RULES.spec("batch", "kv_seq", "kv_heads", None)
    # kv_seq claims 'model'; kv_heads degrades (dedup)
    assert s == jax.sharding.PartitionSpec(("pod", "data"), "model", None,
                                           None)


@pytest.mark.parametrize("rules", [TRAIN_SP_RULES, TRAIN_RULES])
def test_sp_variant_lowers_and_matches(rules, mesh):
    """SP sharding is semantics-preserving: same loss on 1 device."""
    cfg = configs.get_config("llama3.2-1b+smoke")
    tcfg = TrainConfig()
    r = rules.resolve(mesh)
    key = jax.random.PRNGKey(0)
    with use_rules(r, mesh):
        from repro.train import init_train_state

        state = init_train_state(cfg, tcfg, key)
        batch = {
            "tokens": jnp.zeros((4, 64), jnp.int32),
            "labels": jnp.zeros((4, 64), jnp.int32),
        }
        _, metrics = train_step(cfg, tcfg, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))


def test_zero1_variant_lowers(mesh):
    import jax

    cfg = configs.get_config("mixtral-8x7b+smoke")
    tcfg = TrainConfig()
    rules = TRAIN_RULES.resolve(mesh)
    zrules = ZERO1_PARAM_RULES.resolve(mesh)
    with use_rules(rules, mesh):
        state, batch = specs.train_cell_args(
            cfg, SMALL_TRAIN, mesh, rules, tcfg, param_rules=zrules
        )
        lowered = jax.jit(
            functools.partial(train_step, cfg, tcfg), donate_argnums=(0,)
        ).lower(state, batch)
    assert lowered.compile() is not None


def test_logical_axis_size_outside_ctx():
    assert logical_axis_size("batch") == 1


def test_logical_axis_size_in_ctx(mesh):
    with use_rules(TRAIN_RULES.resolve(mesh), mesh):
        assert logical_axis_size("batch") == 1  # 1x1 mesh
        assert logical_axis_size("nonexistent") == 1


def test_moe_shard_local_grouping_preserves_tokens():
    """[B,S,D] -> [G,T/G,D] grouping is a pure reshape: with G=1 the MoE
    output is identical to the previous global formulation (covered by
    the dense-mixture oracle test); here we check G>1 grouping math."""
    import dataclasses

    from repro.models import layers as L

    cfg = configs.get_config("mixtral-8x7b+smoke")
    cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    p = L.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 6, cfg.d_model))
    # same input twice must give same output (determinism incl. scatter)
    y1 = L.moe(p, cfg, x)
    y2 = L.moe(p, cfg, x)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

"""Analog device-model tests: Table I calibration + noise behaviour."""

import numpy as np
import jax
import pytest

from repro.core import device_model as dm


def test_calibrated_model_exact_at_table1():
    cal = dm.default_calibrated()
    res = cal.residuals_table1()
    assert np.abs(res).max() < 0.5, res  # per-die calibration closes Table I


def test_physical_model_rmse_documented():
    p = dm.default_params()
    rmse = float(np.sqrt(np.mean(dm.table1_residuals(p) ** 2)))
    # The silicon surface is non-monotone in V_eval; a smooth 5-parameter
    # physical model cannot do better than ~6-12 HD units RMSE.
    assert rmse < 15.0


def test_vref_monotonicity():
    """Lowering V_ref raises the HD tolerance (paper Sec. III)."""
    p = dm.default_params()
    vr = np.linspace(0.4, 1.2, 20)
    thr = np.asarray(dm.hd_threshold(p, vr, 0.6, 1.1))
    assert (np.diff(thr) <= 1e-6).all()


def test_veval_monotonicity_physical():
    """In the physical model, lowering V_eval slows discharge -> higher
    tolerance (the calibrated model intentionally deviates near Table I
    anchor points)."""
    p = dm.default_params()
    ve = np.linspace(0.4, 1.2, 20)
    thr = np.asarray(dm.hd_threshold(p, 0.8, ve, 1.1))
    assert (np.diff(thr) <= 1e-6).all()


def test_knob_schedule_hits_targets():
    knobs, achieved = dm.knob_schedule(33, 64)
    targets = np.linspace(0, 64, 33)
    assert np.abs(achieved - targets).max() <= 3.0
    assert knobs.shape == (33, 3)
    assert (knobs[:, 0] >= 0.29).all() and (knobs[:, 0] <= 1.21).all()


def test_noise_model_statistics():
    nm = dm.NoiseModel(sigma_hd=2.0, sigma_vref=0.0, sigma_tjitter=0.0)
    p = dm.default_params()
    key = jax.random.PRNGKey(0)
    t = nm.effective_threshold(key, p, 0.8, 0.6, 1.1, shape=(20000,))
    t = np.asarray(t)
    base = float(dm.hd_threshold(p, 0.8, 0.6, 1.1))
    assert abs(t.mean() - base) < 0.1
    assert abs(t.std() - 2.0) < 0.15


def test_noiseless_is_deterministic():
    p = dm.default_params()
    key = jax.random.PRNGKey(0)
    t = dm.NOISELESS.effective_threshold(key, p, 0.8, 0.6, 1.1, shape=(8,))
    assert float(np.asarray(t).std()) == 0.0


def test_energy_model_table2():
    e = dm.EnergyModel()
    assert e.energy_per_cycle_j == pytest.approx(32e-12)  # 0.8mW / 25MHz
    # full-array binary throughput: 4 banks x 2048 x 64 x 2 ops x 25 MHz
    ops = e.ops_per_search(2048, 64) * 4
    assert ops * e.clock_hz == pytest.approx(26.2e12, rel=0.01)

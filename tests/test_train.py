"""Training substrate: optimizer, microbatch equivalence, gradient
compression convergence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.train import TrainConfig, init_train_state
from repro.train.optimizer import OptimizerConfig, apply_updates, init_opt_state
from repro.train.train_step import loss_and_grads, train_step
from repro.train.grad_compress import (
    CompressionConfig,
    compress_with_feedback,
    compression_ratio,
    init_residual,
    sign_compress,
    sign_decompress,
)


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    ocfg = OptimizerConfig(lr=0.1, weight_decay=0.0, warmup_steps=0)
    state = init_opt_state(ocfg, params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, state, m = apply_updates(ocfg, params, g, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_applies():
    params = {"w": jnp.zeros(3)}
    ocfg = OptimizerConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0,
                           warmup_steps=0)
    state = init_opt_state(ocfg, params)
    g = {"w": jnp.array([100.0, 0.0, 0.0])}
    _, _, metrics = apply_updates(ocfg, params, g, state)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)


def test_microbatch_equivalence():
    """grads(mb=1) == grads(mb=4) (linearity of the mean CE loss)."""
    cfg = configs.get_config("llama3.2-1b+smoke")
    key = jax.random.PRNGKey(0)
    from repro.models import model as M

    params = M.init_params(cfg, key)
    b, s = 8, 16
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    l1, g1, _ = loss_and_grads(cfg, TrainConfig(microbatches=1), params, batch)
    l4, g4, _ = loss_and_grads(cfg, TrainConfig(microbatches=4), params, batch)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    for a, b_ in zip(jax.tree_util.tree_leaves(g1),
                     jax.tree_util.tree_leaves(g4)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b_, np.float32),
            atol=1e-5, rtol=1e-4,
        )


def test_sign_compress_roundtrip_scale():
    x = jnp.array([-3.0, 1.0, 0.5, -0.25])
    bits, s = sign_compress(x)
    np.testing.assert_array_equal(np.asarray(bits), [-1, 1, 1, -1])
    y = sign_decompress(bits, s)
    assert float(jnp.sign(y[0])) == -1.0
    # scale preserves mean magnitude
    assert float(s) == pytest.approx(float(jnp.abs(x).mean()))


def test_ef_signsgd_converges_least_squares():
    """EF-signSGD drives a least-squares problem to near-zero loss —
    the error-feedback makes 1-bit gradients unbiased in the limit."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    w = {"w": jnp.zeros(16)}
    res = init_residual(w)
    loss = lambda w_: 0.5 * jnp.mean((A @ w_["w"] - b) ** 2)
    g_fn = jax.grad(loss)
    lr = 0.05
    for _ in range(400):
        g = g_fn(w)
        g_hat, res = compress_with_feedback(g, res)
        w = {"w": w["w"] - lr * g_hat["w"]}
    final = float(loss(w))
    w_star = jnp.linalg.lstsq(A, b)[0]
    opt = float(0.5 * jnp.mean((A @ w_star - b) ** 2))
    assert final < opt + 0.05, (final, opt)


def test_compression_ratio_near_32x():
    params = {"a": jnp.zeros((1024, 1024)), "b": jnp.zeros((4096,))}
    r = compression_ratio(params)
    assert 25.0 < r < 32.0


def test_train_step_with_compression_runs():
    cfg = configs.get_config("llama3.2-1b+smoke")
    tcfg = TrainConfig(compression=CompressionConfig(enabled=True))
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.zeros((2, 16), jnp.int32),
        "labels": jnp.zeros((2, 16), jnp.int32),
    }
    new_state, metrics = train_step(cfg, tcfg, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert "compressed" in metrics


def test_lr_schedule_warmup_and_decay():
    from repro.train.optimizer import schedule

    ocfg = OptimizerConfig(lr=1.0, warmup_steps=10, decay_steps=110)
    lrs = [float(schedule(ocfg, jnp.int32(s))) for s in [0, 5, 10, 60, 109]]
    assert lrs[0] < lrs[1] < lrs[2]  # warmup ramps
    assert lrs[2] == pytest.approx(1.0, abs=0.01)
    assert lrs[3] < lrs[2]  # cosine decays
    assert lrs[4] < 0.01

"""Model-substrate correctness: flash attention vs naive oracle,
prefill/decode consistency, mamba decode==scan, MoE dispatch semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import model as M
from repro.models import ssm as S

F32 = jnp.float32


def _naive_attention(q, k, v, q_pos, k_pos, window):
    """Oracle: dense causal/windowed softmax attention.
    q: [B,G,R,Sq,dh], k/v: [B,G,Sk,dh]."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bgrqd,bgkd->bgrqk", q.astype(F32) * scale, k.astype(F32))
    delta = q_pos[:, None, None, :, None] - k_pos[:, None, None, None, :]
    valid = (delta >= 0) & (delta < window) & (k_pos >= 0)[:, None, None, None, :]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgrqk,bgkd->bgrqd", p, v.astype(F32))


@pytest.mark.parametrize("window,chunk", [(1 << 30, 7), (1 << 30, 16), (5, 4)])
def test_flash_attention_matches_naive(window, chunk):
    key = jax.random.PRNGKey(0)
    b, g, r, sq, dh = 2, 2, 3, 24, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, g, r, sq, dh))
    k = jax.random.normal(ks[1], (b, g, sq, dh))
    v = jax.random.normal(ks[2], (b, g, sq, dh))
    pos = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    got = L._flash_attention(q, k, v, pos, pos, window, chunk)
    want = _naive_attention(q, k, v, pos, pos, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize(
    "arch", ["llama3.2-1b", "mixtral-8x7b", "falcon-mamba-7b",
             "jamba-v0.1-52b", "llama4-maverick"]
)
def test_decode_matches_forward(arch):
    """prefill(S) + decode(S..S+2) logits == forward(S+3) logits at the
    same positions — KV/SSM caches are exact."""
    cfg = configs.get_config(arch + "+smoke")
    if cfg.n_experts:
        # dropless check needs ample capacity
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s, extra = 2, 12, 3
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (b, s + extra), 0, cfg.vocab_size)
    full_logits, _ = M.forward(params, cfg, tokens=toks)

    logits_p, cache = M.prefill(params, cfg, tokens=toks[:, :s])
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, s - 1]),
        atol=3e-3, rtol=3e-3,
    )
    for i in range(extra):
        lg, cache = M.decode(
            params, cfg, cache, toks[:, s + i : s + i + 1], jnp.int32(s + i)
        )
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, s + i]),
            atol=3e-3, rtol=3e-3, err_msg=f"decode step {i}",
        )


def test_sliding_window_decode_matches_forward():
    cfg = configs.get_config("mixtral-8x7b+smoke")
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    assert cfg.sliding_window == 16
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s, extra = 1, 20, 4  # s > window: rolling cache in play
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + extra), 0,
                              cfg.vocab_size)
    full_logits, _ = M.forward(params, cfg, tokens=toks)
    logits_p, cache = M.prefill(params, cfg, tokens=toks[:, :s])
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, s - 1]),
        atol=3e-3, rtol=3e-3,
    )
    for i in range(extra):
        lg, cache = M.decode(
            params, cfg, cache, toks[:, s + i : s + i + 1], jnp.int32(s + i)
        )
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, s + i]),
            atol=3e-3, rtol=3e-3, err_msg=f"rolled decode step {i}",
        )


def test_mamba_block_decode_equals_scan():
    cfg = configs.get_config("falcon-mamba-7b+smoke")
    p = S.init_mamba(cfg, jax.random.PRNGKey(0))
    b, s = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    y_full, _ = S.mamba_block(p, cfg, x)
    cache = S.init_mamba_cache(cfg, b, jnp.float32)
    ys = []
    for t in range(s):
        y_t, cache = S.mamba_block(p, cfg, x[:, t : t + 1], cache=cache)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_full), np.asarray(y_step), atol=1e-4, rtol=1e-4
    )


def test_moe_ample_capacity_equals_dense_mixture():
    """With capacity >= T*k, no token drops: MoE output equals the
    explicit gated mixture over selected experts."""
    cfg = configs.get_config("mixtral-8x7b+smoke")
    cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    p = L.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
    got = L.moe(p, cfg, x)

    # oracle: dense per-token top-k mixture
    t = x.reshape(-1, cfg.d_model)
    logits = t @ p["router"]
    probs = jax.nn.softmax(logits.astype(F32), -1)
    gate, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    outs = []
    for i in range(t.shape[0]):
        acc = jnp.zeros((cfg.d_model,), F32)
        for j in range(cfg.moe_top_k):
            e = int(idx[i, j])
            h = t[i] @ p["w_gate"][e]
            u = t[i] @ p["w_up"][e]
            o = (jax.nn.silu(h) * u) @ p["w_down"][e]
            acc = acc + gate[i, j] * o
        outs.append(acc)
    want = jnp.stack(outs).reshape(2, 6, cfg.d_model)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3
    )


def test_moe_capacity_drops_overflow():
    cfg = configs.get_config("mixtral-8x7b+smoke")
    cfg = dataclasses.replace(cfg, capacity_factor=0.1)
    p = L.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    got = L.moe(p, cfg, x)  # must not error; dropped tokens output ~0
    assert bool(jnp.isfinite(got).all())


def test_qk_norm_path():
    cfg = configs.get_config("chameleon-34b+smoke")
    assert cfg.qk_norm
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    e = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    logits, _ = M.forward(params, cfg, embeds=e)
    assert bool(jnp.isfinite(logits).all())


def test_rope_relative_position_properties():
    cfg = configs.get_config("llama3.2-1b+smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
    l1, _ = M.forward(params, cfg, tokens=toks)
    # RoPE is RELATIVE: a uniform shift leaves logits invariant...
    pos_shift = jnp.broadcast_to(jnp.arange(8)[None] + 5, (1, 8))
    l2, _ = M.forward(params, cfg, tokens=toks, positions=pos_shift)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=2e-3, rtol=2e-3)
    # ...but stretching relative distances changes them (RoPE active)
    pos_stretch = jnp.broadcast_to(2 * jnp.arange(8)[None], (1, 8))
    l3, _ = M.forward(params, cfg, tokens=toks, positions=pos_stretch)
    assert not np.allclose(np.asarray(l1), np.asarray(l3), atol=1e-4)

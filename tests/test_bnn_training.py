"""End-to-end paper pipeline: train binary MLP -> fold BN -> deploy to
CAM -> Algorithm 1 inference.  The reproduction's accuracy claims in
miniature (the full Fig. 5 sweep lives in benchmarks/)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bnn, ensemble, mapping
from repro.data.synthetic import MNIST_LIKE, binarize_images, make_dataset


@pytest.fixture(scope="module")
def trained():
    cfg = bnn.MLPConfig(layer_sizes=(784, 64, 10), bias_cells=64)
    tx, ty, vx, vy = make_dataset(MNIST_LIKE, n_train=3000, n_test=600,
                                  seed=0)
    txb, vxb = binarize_images(tx), binarize_images(vx)
    params = bnn.train_mlp(
        jax.random.PRNGKey(0), cfg, txb, ty, epochs=6, batch=128, lr=2e-3
    )
    return cfg, params, txb, ty, vxb, vy


def test_software_baseline_accuracy(trained):
    cfg, params, txb, ty, vxb, vy = trained
    acc = bnn.eval_accuracy(params, cfg, vxb, vy, topk=(1, 2))
    assert acc["top1"] > 0.85, acc  # synthetic 10-class task is learnable
    assert acc["top2"] >= acc["top1"]


def test_fold_preserves_decisions(trained):
    """Eq. (3): folded integer network reproduces the BN-eval forward's
    hidden activations and logit ranking."""
    cfg, params, txb, ty, vxb, vy = trained
    folded = bnn.fold(params, cfg)
    x = jnp.asarray(vxb[:256])
    pre = bnn.folded_forward_exact(folded, x)
    logits, _ = bnn.forward(params, x, cfg)
    agree = (jnp.argmax(pre, -1) == jnp.argmax(logits, -1)).mean()
    # C_j is clipped to +-bias_cells and rounded: ranking agreement is
    # high but not exact by construction
    assert float(agree) > 0.9, float(agree)


def test_cam_deployment_matches_folded_oracle(trained):
    cfg, params, txb, ty, vxb, vy = trained
    folded = bnn.fold(params, cfg)
    mapped = [mapping.map_layer(l, cfg.bias_cells) for l in folded]
    x = jnp.asarray(vxb[:128])
    h = x
    for ml, fl in zip(mapped[:-1], folded[:-1]):
        h = mapping.layer_forward(ml, h, "exact")
    # fold emits parity-adjusted C_j (y + C never zero), and the CAM's
    # round-down quantization is decision-preserving on that odd grid —
    # so the deployed hidden activations equal the folded oracle's EXACTLY
    oracle_h = jnp.where(
        x @ jnp.asarray(folded[0].weights_pm1.T, jnp.float32)
        + jnp.asarray(folded[0].c, jnp.float32) >= 0, 1.0, -1.0,
    )
    np.testing.assert_array_equal(np.asarray(h), np.asarray(oracle_h))


def test_algorithm1_end_to_end_accuracy(trained):
    """The paper's claim: the binary ensemble reaches the software
    baseline accuracy (within noise) with 33 passes."""
    cfg, params, txb, ty, vxb, vy = trained
    folded = bnn.fold(params, cfg)
    ecfg = ensemble.EnsembleConfig()
    head = ensemble.build_head(folded[-1], ecfg)
    mapped = [mapping.map_layer(l, cfg.bias_cells) for l in folded[:-1]]
    h = jnp.asarray(vxb)
    for ml in mapped:
        h = mapping.layer_forward(ml, h, "exact")
    pred = ensemble.predict(head, h, ecfg)
    acc_cam = float((pred == jnp.asarray(vy)).mean())
    acc_sw = bnn.eval_accuracy(params, cfg, vxb, vy)["top1"]
    assert acc_cam > acc_sw - 0.05, (acc_cam, acc_sw)


def test_hierarchical_mode_accuracy_gap_bounded(trained):
    """The strictly-binary tiled-majority input layer costs accuracy;
    the gap is quantified (DESIGN.md ambiguity resolution)."""
    cfg, params, txb, ty, vxb, vy = trained
    folded = bnn.fold(params, cfg)
    ecfg = ensemble.EnsembleConfig()
    head = ensemble.build_head(folded[-1], ecfg)
    mapped = [mapping.map_layer(l, cfg.bias_cells) for l in folded[:-1]]
    accs = {}
    for mode in ("exact", "hierarchical"):
        h = jnp.asarray(vxb)
        for ml in mapped:
            h = mapping.layer_forward(ml, h, mode)
        pred = ensemble.predict(head, h, ecfg)
        accs[mode] = float((pred == jnp.asarray(vy)).mean())
    assert accs["hierarchical"] > 0.3  # binary-only stays far above chance
    assert accs["exact"] >= accs["hierarchical"] - 0.02

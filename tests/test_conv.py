"""End-to-end-binary CNN workload: bit-exactness vs the unpacked oracle.

The correctness bar for kernels/fused_conv.py and the conv path of
repro/pipeline.py: the packed fused flow (both impls) must be
bit-identical to `kernels.ref.conv_votes_ref` — the ±1 float oracle that
encodes raw pixels through the binary input layer, runs every conv/FC
layer as sign(dot + C), and votes the head — across multiple input
sizes, strides, channel alignments, and the silicon-mode entry points.
"""

import jax
import numpy as np
import pytest

from repro.configs.paper_cnn import HG_CNN, MNIST_CNN, build_cnn_pipeline
from repro.core import convnet
from repro.core.binarize import InputEncoding
from repro.core.convnet import CNNConfig, ConvSpec
from repro.core.device_model import NOISELESS, SILICON
from repro.kernels import ref

# Two input sizes (the acceptance bar asks for >= 2), plus a config with
# non-word-aligned channel counts to exercise the position-wise flatten
# packing, and a conv->head-direct net with no FC hidden layer.
CONFIGS = {
    "mnist-28": CNNConfig(
        side=28, encoding=InputEncoding("thermometer", 8),
        conv=(ConvSpec(3, 32, 2), ConvSpec(3, 32, 2)), hidden=(128,),
        n_classes=10,
    ),
    "hg-64": CNNConfig(
        side=64, encoding=InputEncoding("thermometer", 4),
        conv=(ConvSpec(3, 32, 2), ConvSpec(3, 32, 2)), hidden=(128,),
        n_classes=20,
    ),
    "unaligned-12": CNNConfig(
        side=12, encoding=InputEncoding("thermometer", 3),
        conv=(ConvSpec(3, 24, 2), ConvSpec(3, 20, 1)), hidden=(48,),
        n_classes=7,
    ),
    "head-direct-10": CNNConfig(
        side=10, encoding=InputEncoding("thermometer", 2),
        conv=(ConvSpec(3, 32, 2),), hidden=(), n_classes=5,
    ),
}


def _images(cfg, n, seed=1):
    return np.random.default_rng(seed).random((n, cfg.n_in)).astype(
        np.float32
    )


def _oracle(cfg, folded, head, x):
    return np.asarray(
        ref.conv_votes_ref(folded, head, x, cfg.encoding, cfg.side)
    )


@pytest.mark.parametrize("name", sorted(CONFIGS))
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_conv_pipeline_bit_exact_vs_oracle(name, impl):
    cfg = CONFIGS[name]
    folded = convnet.random_folded_cnn(cfg, seed=sum(map(ord, name)))
    pipe = build_cnn_pipeline(cfg, folded, impl=impl, bq=4)
    x = _images(cfg, 6 if cfg.side >= 64 else 11)
    want = _oracle(cfg, folded, pipe.head, x)
    np.testing.assert_array_equal(np.asarray(pipe.votes(x)), want)
    np.testing.assert_array_equal(
        np.asarray(pipe.predict(x)), want.argmax(-1)
    )


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_conv_noiseless_limit_bit_exact(impl):
    """sigma -> 0: every silicon entry point equals the oracle."""
    cfg = CONFIGS["unaligned-12"]
    folded = convnet.random_folded_cnn(cfg, seed=3)
    pipe = build_cnn_pipeline(cfg, folded, impl=impl, bq=4, noise=NOISELESS)
    x = _images(cfg, 9, seed=2)
    want = _oracle(cfg, folded, pipe.head, x)
    key = jax.random.PRNGKey(7)
    np.testing.assert_array_equal(np.asarray(pipe.votes(x, key)), want)
    mc = np.asarray(pipe.votes_mc(x, key, 3))
    np.testing.assert_array_equal(mc, np.broadcast_to(want, mc.shape))
    cum = np.asarray(pipe.cum_votes(x, key))
    np.testing.assert_array_equal(cum[-1], want)
    keys = jax.random.split(key, x.shape[0])
    np.testing.assert_array_equal(np.asarray(pipe.votes_each(x, keys)), want)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_conv_silicon_impls_agree(impl):
    """Same key => both impls draw identical silicon votes (sampling
    happens outside the kernel), and the draw actually perturbs."""
    cfg = CONFIGS["unaligned-12"]
    folded = convnet.random_folded_cnn(cfg, seed=5)
    pipe = build_cnn_pipeline(cfg, folded, impl=impl, bq=8, noise=SILICON)
    x = _images(cfg, 64, seed=3)  # batch == bucket: shared sample shapes
    key = jax.random.PRNGKey(5)
    got = np.asarray(pipe.votes(x, key))
    assert (got != np.asarray(pipe.votes(x))).any()
    ref_pipe = build_cnn_pipeline(cfg, folded, impl="xla", noise=SILICON)
    np.testing.assert_array_equal(got, np.asarray(ref_pipe.votes(x, key)))


def test_conv_batch_bucketing_invariance():
    cfg = CONFIGS["unaligned-12"]
    folded = convnet.random_folded_cnn(cfg, seed=9)
    pipe = build_cnn_pipeline(cfg, folded, impl="xla", min_bucket=8)
    x = _images(cfg, 21, seed=4)
    full = np.asarray(pipe.votes(x))
    for b in (1, 7, 8, 9, 21):
        np.testing.assert_array_equal(np.asarray(pipe.votes(x[:b])), full[:b])


def test_fold_cnn_smoke_trained_shapes_and_parity():
    """fold_cnn emits dead-zone-free constants and oracle-consistent
    layers for a (briefly) trained model."""
    cfg = CNNConfig(
        side=12, encoding=InputEncoding("thermometer", 2),
        conv=(ConvSpec(3, 8, 2),), hidden=(16,), n_classes=4,
    )
    rng = np.random.default_rng(0)
    tx = rng.random((64, cfg.n_in)).astype(np.float32)
    ty = rng.integers(0, cfg.n_classes, 64)
    params = convnet.train_cnn(jax.random.PRNGKey(0), cfg, tx, ty,
                               epochs=1, batch=32)
    folded = convnet.fold_cnn(params, cfg)
    assert isinstance(folded[0], convnet.FoldedConvLayer)
    assert folded[0].weights_pm1.shape == (8, 3, 3, 2)
    for layer in folded:
        n_bits = (layer.n_bits
                  if isinstance(layer, convnet.FoldedConvLayer)
                  else layer.n_in)
        assert ((layer.c + n_bits) % 2 == 1).all()
        assert (np.abs(layer.c) <= cfg.bias_cells).all()
    pipe = build_cnn_pipeline(cfg, folded, impl="xla")
    x = _images(cfg, 5, seed=6)
    np.testing.assert_array_equal(
        np.asarray(pipe.votes(x)), _oracle(cfg, folded, pipe.head, x)
    )


def test_train_cnn_clips_only_latent_weights():
    """BinaryConnect clipping applies to the latent weights ONLY: BN
    running stats must track real batch statistics (a conv pre-activation
    variance is ~n_bits, far above 1 — clipping it to [-1, 1] corrupts
    every eval/fold that consumes the stats)."""
    cfg = CNNConfig(
        side=12, encoding=InputEncoding("thermometer", 4),
        conv=(ConvSpec(3, 8, 2),), hidden=(), n_classes=4,
    )
    rng = np.random.default_rng(1)
    tx = rng.random((256, cfg.n_in)).astype(np.float32)
    ty = rng.integers(0, cfg.n_classes, 256)
    params = convnet.train_cnn(jax.random.PRNGKey(0), cfg, tx, ty,
                               epochs=2, batch=64)
    var = np.asarray(params["conv"][0]["var"])
    assert var.max() > 1.5, var  # 36-bit dot variance; 1.0 means clipped
    for layer in params["conv"] + params["fc"]:
        w = np.asarray(layer["w"])
        assert w.min() >= -1.0 and w.max() <= 1.0  # latents ARE clipped


def test_compile_pipeline_conv_validation():
    cfg = CONFIGS["head-direct-10"]
    folded = convnet.random_folded_cnn(cfg, seed=1)
    from repro import pipeline
    from repro.core.ensemble import EnsembleConfig

    with pytest.raises(ValueError, match="image_side"):
        pipeline.compile_pipeline(folded, EnsembleConfig())
    with pytest.raises(ValueError, match="conv-only"):
        pipeline.compile_pipeline(folded[-1:], EnsembleConfig(),
                                  image_side=10)
    with pytest.raises(ValueError, match="prefix"):
        pipeline.compile_pipeline(
            [folded[-1], folded[0]], EnsembleConfig(), image_side=10
        )
    with pytest.raises(ValueError, match="encoding width"):
        pipeline.compile_pipeline(
            folded, EnsembleConfig(), image_side=10,
            image_encoding=InputEncoding("thermometer", 5),
        )
    # head-direct with a non-word-aligned last conv is rejected
    bad = CNNConfig(side=10, encoding=InputEncoding("thermometer", 2),
                    conv=(ConvSpec(3, 24, 2),), hidden=(), n_classes=5)
    with pytest.raises(ValueError, match="word-aligned"):
        build_cnn_pipeline(bad, convnet.random_folded_cnn(bad, seed=2))


def test_cnn_configs_consistent():
    """Paper CNN configs: geometry chains and word-aligned flattens."""
    for cfg in (MNIST_CNN, HG_CNN):
        sides = cfg.feature_sides()
        assert sides[0] == cfg.side and len(sides) == len(cfg.conv) + 1
        assert cfg.flat_features == sides[-1] ** 2 * cfg.conv[-1].c_out
        assert cfg.conv[-1].c_out % 32 == 0  # word-aligned flatten
        assert cfg.fc_sizes[-1] == cfg.n_classes
    assert MNIST_CNN.flat_features == 6 * 6 * 32 == 1152
    assert HG_CNN.flat_features == 15 * 15 * 32 == 7200


def test_conv_served_bit_exact():
    """The CNN is servable day one: served noiseless and silicon-mode
    (per-request-key) results are bit-exact vs direct pipeline calls,
    however the batcher coalesces the stream."""
    from repro.serve.picbnn import BatchingPolicy, PicBnnServer

    cfg = CONFIGS["unaligned-12"]
    folded = convnet.random_folded_cnn(cfg, seed=11)
    pipe = build_cnn_pipeline(cfg, folded, impl="xla", min_bucket=8,
                              max_bucket=32)
    pipe_si = build_cnn_pipeline(cfg, folded, impl="xla", min_bucket=8,
                                 max_bucket=32, noise=SILICON)
    x = _images(cfg, 24, seed=8)
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(3), 24))
    direct = np.asarray(pipe.predict(x))
    direct_si = np.asarray(pipe_si.predict_each(x, keys))
    srv = PicBnnServer(BatchingPolicy(max_batch=32, max_wait_us=200))
    srv.register("cnn", pipe)
    srv.register("cnn-si", pipe_si)
    with srv:
        h = srv.submit_many("cnn", x)
        h_si = srv.submit_many("cnn-si", x, keys=keys)
        np.testing.assert_array_equal(h.wait_all(timeout=60), direct)
        np.testing.assert_array_equal(h_si.wait_all(timeout=60), direct_si)

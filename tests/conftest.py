import os

# Tests run against the real host device topology (1 CPU device here) —
# only launch/dryrun.py forces the 512-device placeholder platform.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)

import os

# Tests run against the real host device topology (1 CPU device here) —
# only launch/dryrun.py forces the 512-device placeholder platform.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_addoption(parser):
    parser.addoption(
        "--run-slow",
        action="store_true",
        default=False,
        help="run slow/opt-in Monte-Carlo sweeps (skipped in tier-1)",
    )


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: opt-in full Monte-Carlo sweeps; run with --run-slow",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="slow Monte-Carlo sweep: needs --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)

"""CAM bank mapping: tiling exactness, hierarchical-MAJ semantics, and
the silicon cycle/energy model vs Table II."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip when hypothesis is absent
    from _hypothesis_compat import given, settings, st

from repro.core import binarize, bnn, mapping
from repro.core.device_model import EnergyModel

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def _layer(rng, n_out, n_in, cmax=30):
    return bnn.FoldedLayer(
        weights_pm1=rng.choice([-1, 1], (n_out, n_in)).astype(np.int8),
        c=rng.integers(-cmax, cmax + 1, n_out),
    )


@given(st.integers(1, 300), st.integers(5, 900), st.integers(0, 100))
def test_tiled_exact_equals_oracle(n_out, n_in, seed):
    rng = np.random.default_rng(seed)
    layer = _layer(rng, n_out, n_in)
    ml = mapping.map_layer(layer, bias_cells=64)
    x = binarize.random_pm1(jax.random.PRNGKey(seed), (4, n_in))
    got = mapping.layer_forward(ml, x, "exact")
    # the CAM realizes C_j with parity-matched bias cells: odd (c + B)
    # rounds c down by one (decision-preserving 1-LSB quantization)
    c = layer.c.copy()
    odd = (c + 64) % 2 != 0
    c = np.where(odd, c - 1, c)
    want = jnp.where(
        x @ jnp.asarray(layer.weights_pm1.T, jnp.float32)
        + jnp.asarray(c, jnp.float32) >= 0, 1.0, -1.0,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_single_tile_hierarchical_equals_exact():
    """With one column tile, MAJ-of-MAJ degenerates to exact Eq. (3)."""
    rng = np.random.default_rng(0)
    layer = _layer(rng, 64, 128)
    ml = mapping.map_layer(layer, bias_cells=64)
    assert len(ml.col_tiles) == 1
    x = binarize.random_pm1(jax.random.PRNGKey(1), (16, 128))
    a = mapping.layer_forward(ml, x, "exact")
    b = mapping.layer_forward(ml, x, "hierarchical")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plan_layer_single_cycle_configs():
    """Paper Sec. V-B: layers up to 256x512 / 128x1024 / 64x2048 execute
    in one cycle (bias cells ride within the row budget here)."""
    for n_in, n_out in [(192, 512), (64, 1024), (0, 0)]:
        if n_in == 0:
            continue
        plan = mapping.plan_layer(n_out, n_in, bias_cells=64)
        assert plan.cycles_per_query == 1, (n_in, n_out, plan)


def test_plan_layer_mnist_shapes():
    # input layer 784 -> 128: 784+64 bias = 848 bits -> 4 tiles of 256
    p1 = mapping.plan_layer(128, 784, 64)
    assert p1.cycles_per_query == 4
    # output layer 128 -> 10: single search
    p2 = mapping.plan_layer(10, 128, 64)
    assert p2.cycles_per_query == 1


def test_inference_cost_reproduces_paper_throughput():
    """560K inf/s at 25 MHz for the MNIST MLP with 33 output passes."""
    plans = [mapping.plan_layer(128, 784, 64), mapping.plan_layer(10, 128, 64)]
    cost = mapping.model_inference_cost(plans, n_output_passes=33)
    # 4 cycles input layer + 33 cycles output + amortized tuning
    ips = cost.inferences_per_s
    assert 500e3 <= ips <= 700e3, ips  # paper: 560K inf/s
    # energy efficiency: inferences/J == inferences/s/W (paper: 703M)
    inf_per_j = 1.0 / cost.energy_j
    assert 300e6 <= inf_per_j <= 1.5e9, inf_per_j


def test_bias_cells_encoding():
    """C_j realized as 2p - B matching cells (paper Sec. IV example)."""
    from repro.core.cam import write_weights_with_bias, query_with_bias

    w = np.ones((1, 8), np.int8)
    for c in [-12, -3, 0, 5, 12]:
        cam = write_weights_with_bias(w, np.array([c]), bias_cells=12)
        x = jnp.ones((1, 8))
        q = query_with_bias(x, 12)
        hd = int(np.asarray(cam.search_hd(q))[0, 0])
        dot = (8 + 12) - 2 * hd
        expect_c = c if (c + 12) % 2 == 0 else c - 1
        assert dot == 8 + expect_c, (c, dot)

"""Data pipeline: determinism, host sharding disjointness, memmap reads."""

import numpy as np
import pytest

from repro.data.synthetic import HG_LIKE, MNIST_LIKE, binarize_images, make_dataset
from repro.data.tokens import (
    DataConfig,
    memmap_stream,
    synthetic_stream,
    write_token_file,
)


def test_synthetic_dataset_shapes_and_determinism():
    a = make_dataset(MNIST_LIKE, n_train=100, n_test=50, seed=3)
    b = make_dataset(MNIST_LIKE, n_train=100, n_test=50, seed=3)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    tx, ty, vx, vy = a
    assert tx.shape == (100, 784) and vx.shape == (50, 784)
    assert set(np.unique(ty)).issubset(range(10))


def test_hg_spec():
    tx, ty, vx, vy = make_dataset(HG_LIKE, n_train=40, n_test=10)
    assert tx.shape == (40, 4096)
    assert set(np.unique(np.concatenate([ty, vy]))).issubset(range(20))


def test_binarize_images_pm1():
    x = np.array([[0.0, 0.4, 0.5, 1.0]])
    np.testing.assert_array_equal(binarize_images(x), [[-1, -1, 1, 1]])


def test_augmentation_never_wraps():
    """Shift/shear augmentation zero-fills at the frame edge — content
    leaving one side must NOT reappear on the opposite side (the 64x64
    HG glyphs draw near-edge strokes, so np.roll-style wrap-around was
    silent label noise at CNN input widths)."""
    from repro.data.synthetic import _augment, _shift_fill

    rng = np.random.default_rng(0)
    for side in (28, 64):
        # a template with content ONLY on the left edge column band
        template = np.zeros((side, side), np.float32)
        template[:, :2] = 1.0
        for trial in range(32):
            out = _augment(np.random.default_rng(trial), template, 0.0)
            # zero noise: any pixel on the far right could only have
            # arrived by wrapping (max rightward shift+shear ~ side//8)
            assert not out[:, side // 2:].any(), (side, trial)
    # _shift_fill drops, never wraps, in both directions/axes
    a = np.zeros((4, 4), np.float32)
    a[0, 0] = 1.0
    assert _shift_fill(a, -1, 0).sum() == 0.0
    assert _shift_fill(a, -1, 1).sum() == 0.0
    assert _shift_fill(a, 1, 0)[1, 0] == 1.0
    np.testing.assert_array_equal(_shift_fill(a, 0, 0), a)


def test_glyph_template_rejects_tiny_sides():
    from repro.data.synthetic import _glyph_template

    with pytest.raises(ValueError, match="side"):
        _glyph_template(np.random.default_rng(0), 4)


def test_synthetic_stream_restart_determinism():
    cfg = DataConfig(batch=4, seq_len=16, vocab_size=100, seed=5)
    it = synthetic_stream(cfg)
    batches = [next(it) for _ in range(5)]
    it2 = synthetic_stream(cfg)
    for i in range(5):
        b = next(it2)
        np.testing.assert_array_equal(b["tokens"], batches[i]["tokens"])


def test_synthetic_stream_labels_are_shifted_tokens():
    cfg = DataConfig(batch=2, seq_len=8, vocab_size=50)
    b = next(synthetic_stream(cfg))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert (b["tokens"] > 0).all() and (b["tokens"] < 50).all()


def test_host_sharding_disjoint_and_complete():
    full = DataConfig(batch=8, seq_len=4, vocab_size=100, seed=1)
    parts = [
        DataConfig(batch=8, seq_len=4, vocab_size=100, seed=1,
                   host_index=h, host_count=4)
        for h in range(4)
    ]
    # same step across hosts: per-host batches must tile the global batch
    host_batches = [next(synthetic_stream(p))["tokens"] for p in parts]
    assert all(hb.shape == (2, 4) for hb in host_batches)


def test_memmap_stream(tmp_path):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 1000, 10_000).astype(np.uint32)
    f = tmp_path / "tokens.bin"
    write_token_file(f, toks)
    cfg = DataConfig(batch=4, seq_len=16, vocab_size=1000)
    it = memmap_stream(f, cfg)
    b = next(it)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # restartable: start_step > 0 matches the continued stream
    it0 = memmap_stream(f, cfg, start_step=0)
    next(it0)
    b1_cont = next(it0)
    it1 = memmap_stream(f, cfg, start_step=1)
    b1_jump = next(it1)
    np.testing.assert_array_equal(b1_cont["tokens"], b1_jump["tokens"])

"""Data pipeline: determinism, host sharding disjointness, memmap reads."""

import numpy as np
import pytest

from repro.data.synthetic import HG_LIKE, MNIST_LIKE, binarize_images, make_dataset
from repro.data.tokens import (
    DataConfig,
    memmap_stream,
    synthetic_stream,
    write_token_file,
)


def test_synthetic_dataset_shapes_and_determinism():
    a = make_dataset(MNIST_LIKE, n_train=100, n_test=50, seed=3)
    b = make_dataset(MNIST_LIKE, n_train=100, n_test=50, seed=3)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    tx, ty, vx, vy = a
    assert tx.shape == (100, 784) and vx.shape == (50, 784)
    assert set(np.unique(ty)).issubset(range(10))


def test_hg_spec():
    tx, ty, vx, vy = make_dataset(HG_LIKE, n_train=40, n_test=10)
    assert tx.shape == (40, 4096)
    assert set(np.unique(np.concatenate([ty, vy]))).issubset(range(20))


def test_binarize_images_pm1():
    x = np.array([[0.0, 0.4, 0.5, 1.0]])
    np.testing.assert_array_equal(binarize_images(x), [[-1, -1, 1, 1]])


def test_synthetic_stream_restart_determinism():
    cfg = DataConfig(batch=4, seq_len=16, vocab_size=100, seed=5)
    it = synthetic_stream(cfg)
    batches = [next(it) for _ in range(5)]
    it2 = synthetic_stream(cfg)
    for i in range(5):
        b = next(it2)
        np.testing.assert_array_equal(b["tokens"], batches[i]["tokens"])


def test_synthetic_stream_labels_are_shifted_tokens():
    cfg = DataConfig(batch=2, seq_len=8, vocab_size=50)
    b = next(synthetic_stream(cfg))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert (b["tokens"] > 0).all() and (b["tokens"] < 50).all()


def test_host_sharding_disjoint_and_complete():
    full = DataConfig(batch=8, seq_len=4, vocab_size=100, seed=1)
    parts = [
        DataConfig(batch=8, seq_len=4, vocab_size=100, seed=1,
                   host_index=h, host_count=4)
        for h in range(4)
    ]
    # same step across hosts: per-host batches must tile the global batch
    host_batches = [next(synthetic_stream(p))["tokens"] for p in parts]
    assert all(hb.shape == (2, 4) for hb in host_batches)


def test_memmap_stream(tmp_path):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 1000, 10_000).astype(np.uint32)
    f = tmp_path / "tokens.bin"
    write_token_file(f, toks)
    cfg = DataConfig(batch=4, seq_len=16, vocab_size=1000)
    it = memmap_stream(f, cfg)
    b = next(it)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # restartable: start_step > 0 matches the continued stream
    it0 = memmap_stream(f, cfg, start_step=0)
    next(it0)
    b1_cont = next(it0)
    it1 = memmap_stream(f, cfg, start_step=1)
    b1_jump = next(it1)
    np.testing.assert_array_equal(b1_cont["tokens"], b1_jump["tokens"])

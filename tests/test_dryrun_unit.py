"""Dry-run machinery on a 1x1 mesh (unit-level; the 512-device sweep runs
via `python -m repro.launch.dryrun` and its results are validated here)."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.base import SHAPES, ShapeConfig, applicable_shapes
from repro.launch import specs
from repro.launch.roofline import derive, model_flops
from repro.sharding import SERVE_RULES, TRAIN_RULES
from repro.serve.steps import decode_step, prefill_step
from repro.train import TrainConfig
from repro.train.train_step import train_step

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

SMALL_TRAIN = ShapeConfig("train_4k", "train", 64, 4)
SMALL_PREFILL = ShapeConfig("prefill_32k", "prefill", 64, 2)
SMALL_DECODE = ShapeConfig("decode_32k", "decode", 64, 2)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ["llama3.2-1b", "jamba-v0.1-52b"])
def test_lower_train_cell_smoke_mesh(arch, mesh):
    import functools

    from repro.sharding import use_rules

    cfg = configs.get_config(arch + "+smoke")
    rules = TRAIN_RULES.resolve(mesh)
    tcfg = TrainConfig()
    with use_rules(rules, mesh):
        state, batch = specs.train_cell_args(cfg, SMALL_TRAIN, mesh, rules, tcfg)
        lowered = jax.jit(
            functools.partial(train_step, cfg, tcfg), donate_argnums=(0,)
        ).lower(state, batch)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    assert ma.argument_size_in_bytes > 0
    from repro.launch.hlo_cost import cost_analysis_dict

    assert cost_analysis_dict(compiled)["flops"] > 0


@pytest.mark.parametrize("arch", ["mixtral-8x7b"])
def test_lower_decode_cell_smoke_mesh(arch, mesh):
    import functools

    from repro.sharding import use_rules

    cfg = configs.get_config(arch + "+smoke")
    rules = SERVE_RULES.resolve(mesh)
    with use_rules(rules, mesh):
        args = specs.decode_cell_args(cfg, SMALL_DECODE, mesh, rules)
        lowered = jax.jit(
            functools.partial(decode_step, cfg), donate_argnums=(1,)
        ).lower(*args)
    assert lowered.compile() is not None


def test_input_specs_cover_all_kinds():
    cfg = configs.get_config("llama3.2-1b")
    for s in SHAPES.values():
        sp = specs.input_specs(cfg, s)
        assert all(isinstance(v, jax.ShapeDtypeStruct) for v in sp.values())
        if s.kind == "train":
            assert sp["tokens"].shape == (s.global_batch, s.seq_len)
            assert sp["labels"].shape == (s.global_batch, s.seq_len)
        if s.kind == "decode":
            assert sp["tokens"].shape == (s.global_batch, 1)
    vlm = configs.get_config("chameleon-34b")
    sp = specs.input_specs(vlm, SHAPES["prefill_32k"])
    assert sp["embeds"].shape == (32, 32768, vlm.d_model)  # frontend stub


def test_model_flops_scaling_laws():
    cfg = configs.get_config("llama3.2-1b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    de = model_flops(cfg, SHAPES["decode_32k"])
    # train matmul flops ~ 6 N D (the classic estimate), within 25%
    n = cfg.param_count()
    ratio = tr["matmul"] / (6.0 * n * SHAPES["train_4k"].tokens)
    assert 0.75 < ratio < 1.25, ratio
    # per-token attention: decode reads the FULL cache (S), prefill
    # averages S/2 under causal masking -> exactly a 2x ratio
    de_att = de["attention"] / de["tokens"]
    pf_att = pf["attention"] / pf["tokens"]
    assert de_att == pytest.approx(2.0 * pf_att, rel=0.01)
    # total step flops: decode (1 token/seq) << prefill (S tokens/seq)
    assert de["total"] < pf["total"] / 100


def test_roofline_derive_bottleneck_logic():
    cfg = configs.get_config("llama3.2-1b")
    rep = derive(cfg, SHAPES["train_4k"], 256,
                 device_flops=1e12, device_hbm_bytes=1e9,
                 device_wire_bytes=1e6)
    assert rep.bottleneck == "compute"
    rep = derive(cfg, SHAPES["train_4k"], 256,
                 device_flops=1e9, device_hbm_bytes=1e12,
                 device_wire_bytes=1e6)
    assert rep.bottleneck == "memory"
    assert 0.0 <= rep.roofline_fraction <= 1.0


def test_sweep_results_complete_and_green():
    """Deliverable (e): every (arch x applicable shape x mesh) compiled."""
    if not RESULTS.exists():
        pytest.skip("dry-run sweep not executed in this checkout")
    missing, failed = [], []
    for arch in configs.list_archs():
        cfg = configs.get_config(arch)
        app = {s.name for s in applicable_shapes(cfg)}
        for shape in SHAPES:
            for mesh_tag in ("pod", "multipod"):
                p = RESULTS / f"{arch}__{shape}__{mesh_tag}.json"
                if not p.exists():
                    missing.append(p.name)
                    continue
                rec = json.loads(p.read_text())
                if shape in app and rec.get("status") != "ok":
                    failed.append(p.name)
                if shape not in app and rec.get("status") not in (
                    "skipped", "ok"
                ):
                    failed.append(p.name)
    assert not missing, f"missing cells: {missing[:8]}"
    assert not failed, f"failed cells: {failed[:8]}"

"""Checkpoint semantics: roundtrip, atomicity, async, retention, elastic."""

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
            "blocks": {"scale": jnp.asarray(rng.normal(size=(3, 4)))},
        },
        "opt": {"step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save(tmp_path, 3, t)
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t
    )
    restored, step = restore(tmp_path, None, template)
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_and_retention(tmp_path):
    t = _tree()
    for s in [1, 2, 3, 4, 5]:
        save(tmp_path, s, t, keep_last=2)
    assert latest_step(tmp_path) == 5
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_00000004", "step_00000005"]


def test_atomicity_tmp_dirs_invisible(tmp_path):
    t = _tree()
    save(tmp_path, 1, t)
    # a stale tmp dir (crash artifact) must not be seen as a checkpoint
    (tmp_path / ".step_00000009.tmp-dead").mkdir()
    assert latest_step(tmp_path) == 1


def test_restore_shape_mismatch_raises(tmp_path):
    t = _tree()
    save(tmp_path, 1, t)
    bad = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(
            ((x.shape[0] + 1,) + x.shape[1:]) if x.ndim else x.shape, x.dtype
        ),
        t,
    )
    bad["params"]["w"] = jax.ShapeDtypeStruct((9, 9), jnp.float32)
    with pytest.raises(ValueError, match="shape mismatch"):
        restore(tmp_path, 1, bad)


def test_restore_missing_leaf_raises(tmp_path):
    t = _tree()
    save(tmp_path, 1, t)
    template = {**t, "extra": jnp.zeros(3)}
    with pytest.raises(KeyError):
        restore(tmp_path, 1, template)


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep_last=2)
    t = _tree()
    ck.save_async(10, t)
    ck.wait()
    assert latest_step(tmp_path) == 10
    # second save while idle
    ck.save_async(20, t)
    ck.wait()
    assert latest_step(tmp_path) == 20


def test_elastic_restore_with_shardings(tmp_path):
    """Restore against explicit (single-device) shardings — the elastic
    path: arrays are device_put against the new mesh's shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    t = _tree()
    save(tmp_path, 2, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, P()), t
    )
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t
    )
    restored, step = restore(tmp_path, 2, template, sh)
    assert step == 2
    leaf = jax.tree_util.tree_leaves(restored)[0]
    assert leaf.sharding.mesh.axis_names == ("data", "model")

"""Fallback shims for test modules when `hypothesis` is not installed.

The property tests decorate with ``@given(...)`` at import time, so a
missing hypothesis kills collection of the whole module (and, under
``pytest -x``, the whole suite).  Importing ``given``/``settings``/``st``
from here instead turns every property test into a skip while the plain
tests in the same module still collect and run.

Usage in a test module:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:  # pragma: no cover - depends on environment
        from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

import pytest


class _AnyStrategy:
    """Stands in for `hypothesis.strategies`: any attribute access, call,
    or chained combinator returns the same inert object."""

    def __getattr__(self, name):
        return self

    def __call__(self, *args, **kwargs):
        return self


st = _AnyStrategy()


class settings:  # noqa: N801 - mirrors the hypothesis class name
    @staticmethod
    def register_profile(*args, **kwargs):
        pass

    @staticmethod
    def load_profile(*args, **kwargs):
        pass


def given(*args, **kwargs):
    def decorate(fn):
        return pytest.mark.skip(reason="hypothesis not installed")(fn)

    return decorate

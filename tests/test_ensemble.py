"""Algorithm 1 invariants: the paper's core claims as properties."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip when hypothesis is absent
    from _hypothesis_compat import given, settings, st

from repro.core import binarize, bnn, ensemble
from repro.core.device_model import NoiseModel

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _random_head(seed, n_classes=10, n_in=128):
    rng = np.random.default_rng(seed)
    layer = bnn.FoldedLayer(
        weights_pm1=rng.choice([-1, 1], (n_classes, n_in)).astype(np.int8),
        c=rng.integers(-30, 31, n_classes),
    )
    cfg = ensemble.EnsembleConfig()
    return ensemble.build_head(layer, cfg), layer, cfg


@given(st.integers(0, 1000))
def test_fused_equals_faithful_noiseless(seed):
    head, layer, cfg = _random_head(seed)
    x = binarize.random_pm1(jax.random.PRNGKey(seed), (16, 128))
    vf = ensemble.votes_faithful(head, x)
    vz = ensemble.votes_fused(head, x)
    np.testing.assert_array_equal(np.asarray(vf), np.asarray(vz))


@given(st.integers(0, 1000))
def test_votes_monotone_in_hd(seed):
    """votes_j is a non-increasing function of HD_j (the LLN mechanism)."""
    head, layer, cfg = _random_head(seed)
    x = binarize.random_pm1(jax.random.PRNGKey(seed + 1), (8, 128))
    from repro.core.cam import query_with_bias

    q = query_with_bias(x, head.bias_cells)
    hd = np.asarray(head.cam.search_hd(q))
    votes = np.asarray(ensemble.votes_fused(head, x))
    for b in range(hd.shape[0]):
        order = np.argsort(hd[b])
        v_sorted = votes[b][order]
        assert (np.diff(v_sorted) <= 0).all()


@given(st.integers(0, 500))
def test_argmax_votes_recovers_argmax_logit(seed):
    """Ties aside (the step-2 sweep quantization), the binary ensemble
    recovers the full-precision logit ranking — the paper's main claim.
    The oracle logits use the CAM's parity-quantized C_j (odd C with even
    bias-cell count rounds 1 LSB down, as in silicon)."""
    head, layer, cfg = _random_head(seed)
    x = binarize.random_pm1(jax.random.PRNGKey(seed + 2), (32, 128))
    c = layer.c.copy()
    odd = (c + cfg.bias_cells) % 2 != 0
    c = np.where(odd, c - 1, c)
    logits = x @ jnp.asarray(layer.weights_pm1.T, jnp.float32) + jnp.asarray(
        c, jnp.float32
    )
    votes = np.asarray(ensemble.votes_fused(head, x))
    pred_v = votes.argmax(-1)
    logits = np.asarray(logits)
    pred_l = logits.argmax(-1)
    agree = 0
    for b in range(32):
        if pred_v[b] == pred_l[b]:
            agree += 1
        else:
            # disagreement is only legal on a vote tie caused by the
            # sweep's step-2 quantization of HD
            assert votes[b, pred_v[b]] == votes[b, pred_l[b]]
    assert agree >= 24  # ties are rare


def test_noise_degrades_gracefully():
    """Under PVT noise the multi-pass majority still tracks the ranking
    (LLN); single-pass matching does not."""
    head, layer, cfg = _random_head(7)
    key = jax.random.PRNGKey(0)
    x = binarize.random_pm1(key, (256, 128))
    logits = np.asarray(
        x @ jnp.asarray(layer.weights_pm1.T, jnp.float32)
        + jnp.asarray(layer.c, jnp.float32)
    )
    gold = logits.argmax(-1)
    noise = NoiseModel(sigma_hd=2.0)
    v = ensemble.votes_faithful(head, x, noise=noise, key=key)
    acc_multi = (np.asarray(v).argmax(-1) == gold).mean()
    assert acc_multi > 0.8


def test_accuracy_sweep_reports_all_pass_counts():
    head, layer, cfg = _random_head(3)
    x = binarize.random_pm1(jax.random.PRNGKey(5), (64, 128))
    logits = np.asarray(
        x @ jnp.asarray(layer.weights_pm1.T, jnp.float32)
        + jnp.asarray(layer.c, jnp.float32)
    )
    labels = logits.argmax(-1)
    out = ensemble.accuracy_sweep(head, x, labels, cfg)
    assert set(out) == set(range(1, 34))
    # with all 33 passes and noiseless compare, top-1 vs own-logit labels
    # is near-perfect (ties only)
    assert out[33]["top1"] >= 0.9
    assert out[33]["top2"] >= out[33]["top1"]


def test_kernel_mode_matches_fused():
    head, layer, cfg = _random_head(11)
    x = binarize.random_pm1(jax.random.PRNGKey(9), (16, 128))
    vk = ensemble.votes_kernel(head, x)
    vz = ensemble.votes_fused(head, x)
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vz))

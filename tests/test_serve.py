"""Serving engine + CAM-head decode semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import binary_lm, model as M
from repro.serve.engine import Engine, EngineConfig, Request
from repro.serve.steps import greedy_sample


@pytest.fixture(scope="module")
def small_lm():
    cfg = configs.get_config("llama3.2-1b+smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_generates_requested_tokens(small_lm):
    cfg, params = small_lm
    eng = Engine(cfg, params, EngineConfig(max_batch=2, eos_id=-1))
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(1, 100, 8).astype(np.int32),
                max_new_tokens=5)
        for i in range(3)
    ]
    out = eng.generate(reqs)
    assert [r.uid for r in out] == [0, 1, 2]
    assert all(len(r.tokens) == 5 for r in out)


def test_engine_greedy_matches_forward(small_lm):
    """Engine greedy decode == argmax over the training-mode forward —
    the serving path and the training path implement the same model."""
    cfg, params = small_lm
    rng = np.random.default_rng(1)
    prompt = rng.integers(1, 100, 10).astype(np.int32)
    eng = Engine(cfg, params, EngineConfig(max_batch=1, eos_id=-1))
    out = eng.generate([Request(uid=0, prompt=prompt, max_new_tokens=4)])[0]

    toks = list(prompt)
    for _ in range(4):
        logits, _ = M.forward(
            params, cfg, tokens=jnp.asarray([toks], jnp.int32)
        )
        toks.append(int(jnp.argmax(logits[0, -1])))
    np.testing.assert_array_equal(out.tokens, toks[len(prompt):])


def test_eos_short_circuits(small_lm):
    cfg, params = small_lm
    # pick the token the model emits first and make IT the eos
    eng0 = Engine(cfg, params, EngineConfig(max_batch=1, eos_id=-1))
    first = eng0.generate(
        [Request(uid=0, prompt=np.arange(1, 9, dtype=np.int32),
                 max_new_tokens=3)]
    )[0].tokens[0]
    eng = Engine(cfg, params, EngineConfig(max_batch=1, eos_id=first))
    out = eng.generate(
        [Request(uid=0, prompt=np.arange(1, 9, dtype=np.int32),
                 max_new_tokens=8)]
    )[0]
    assert out.tokens[0] == first and len(out.tokens) == 1


def test_cam_head_votes_track_dot_ranking():
    """argmax(CAM votes) == argmax(binary dot) up to step-2 sweep ties —
    the LM-head version of the paper's main property."""
    cfg = configs.get_config("musicgen-medium+smoke+cam-head")
    key = jax.random.PRNGKey(0)
    p = binary_lm.init_cam_head(cfg, key)
    h = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    votes = binary_lm.cam_head_logits(p, cfg, h)
    hb = jnp.where(h >= 0, 1.0, -1.0)
    rb = jnp.where(p["rows"] >= 0, 1.0, -1.0)
    dots = hb @ rb.T
    v = np.asarray(votes)
    d = np.asarray(dots)
    agree = 0
    for b in range(64):
        if v[b].argmax() == d[b].argmax():
            agree += 1
        else:
            # every disagreement must be a vote tie (sweep quantization)
            assert v[b, v[b].argmax()] == v[b, d[b].argmax()]
    # at 2048 classes the near-ties are common; correctness is the tie
    # property above, agreement is a soft lower bound
    assert agree >= 20


def test_greedy_sample():
    logits = jnp.asarray([[0.1, 3.0, -1.0], [5.0, 0.0, 0.0]])
    np.testing.assert_array_equal(np.asarray(greedy_sample(logits)), [1, 0])

"""Per-architecture smoke tests (assignment deliverable f).

Every assigned architecture instantiates a REDUCED config of the same
family (same GQA ratio / MoE routing / hybrid interleave / window
pattern, tiny widths) and runs one forward + one train step + one
prefill/decode on CPU, asserting output shapes and finiteness.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.train import TrainConfig, init_train_state
from repro.train.train_step import train_step

ARCHS = configs.list_archs()


def _batch(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.embeds_input:
        return {
            "embeds": jax.random.normal(key, (b, s, cfg.d_model), jnp.float32),
            "labels": labels,
        }
    return {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": labels,
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_config(arch + "+smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    kw = (
        {"embeds": batch["embeds"]} if cfg.embeds_input
        else {"tokens": batch["tokens"]}
    )
    logits, _ = M.forward(params, cfg, **kw)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = configs.get_config(arch + "+smoke")
    tcfg = TrainConfig()
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    new_state, metrics = train_step(cfg, tcfg, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    before = jax.tree_util.tree_leaves(state["params"])[0]
    after = jax.tree_util.tree_leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))
    # loss decreases after repeated steps on the SAME batch (sanity)
    s = new_state
    for _ in range(3):
        s, m2 = train_step(cfg, tcfg, s, batch)
    assert float(m2["loss"]) < float(metrics["loss"]) + 0.5


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch):
    cfg = configs.get_config(arch + "+smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    kw = (
        {"embeds": batch["embeds"]} if cfg.embeds_input
        else {"tokens": batch["tokens"]}
    )
    logits, cache = M.prefill(params, cfg, **kw)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    tok = (
        jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model))
        if cfg.embeds_input
        else jnp.zeros((b, 1), jnp.int32)
    )
    lg, cache2 = M.decode(params, cfg, cache, tok, jnp.int32(s))
    assert lg.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all())
    # cache structure preserved
    jax.tree_util.tree_all(
        jax.tree_util.tree_map(lambda a, b_: a.shape == b_.shape, cache, cache2)
    )


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mixtral-8x7b"])
def test_binary_ffn_variant(arch):
    cfg = configs.get_config(arch + "+smoke+binary-ffn")
    assert cfg.binary_ffn
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = M.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: M.loss_fn(p, cfg, batch)[0])(params)
    gn = sum(
        float(jnp.abs(g).sum()) for g in jax.tree_util.tree_leaves(grads)
    )
    assert np.isfinite(gn) and gn > 0  # STE passes gradients


@pytest.mark.parametrize("arch", ["musicgen-medium"])
def test_cam_head_variant(arch):
    cfg = configs.get_config(arch + "+smoke+cam-head")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    batch = _batch(cfg, b, s)
    logits, cache = M.prefill(params, cfg, embeds=batch["embeds"])
    tok = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model))
    lg, _ = M.decode(params, cfg, cache, tok, jnp.int32(s))
    assert lg.shape == (b, cfg.vocab_size)
    # CAM-head 'logits' are vote counts in [0, n_thresholds]
    assert float(lg.min()) >= 0.0
    assert float(lg.max()) <= cfg.cam_head_thresholds

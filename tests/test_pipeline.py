"""Fused packed-domain pipeline: bit-exactness vs the digital oracle.

The correctness bar for kernels/fused_mlp.py and repro/pipeline.py: the
fused end-to-end flow must be bit-identical to `bnn.folded_forward_exact`
(hidden layers) + `ensemble.votes_fused` (head), across the three logical
bank configurations of the silicon macro, for both implementations
(pallas-interpret and the single-program XLA twin).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import pipeline
from repro.core import binarize, bnn, ensemble
from repro.core.cam import pick_bank_config

# Net shapes whose head rows (n_hidden + 64 bias cells) land on each of
# the macro's three logical row widths: 256 / 128 / 64 bits.
BANK_NETS = {
    "512x256": (300, 192, 12),  # head row 192 + 64 = 256 bits
    "1024x128": (784, 64, 10),  # head row 64 + 64 = 128 bits
    "2048x64": (96, 32, 5),  # head row 32 + 32 = 64 bits (32 bias cells)
}
BANK_BIAS = {"512x256": 64, "1024x128": 64, "2048x64": 32}


def _random_folded(sizes, seed, bias_cells):
    """Random deployed net with fold-style parity-adjusted C_j."""
    rng = np.random.default_rng(seed)
    layers = []
    for i in range(len(sizes) - 1):
        n_in, n_out = sizes[i], sizes[i + 1]
        c = bnn.parity_adjust_c(
            rng.integers(-bias_cells, bias_cells + 1, n_out), n_in, bias_cells
        )
        layers.append(bnn.FoldedLayer(
            weights_pm1=rng.choice([-1, 1], (n_out, n_in)).astype(np.int8),
            c=c,
        ))
    return layers


def _oracle_votes(folded, head, x):
    """Digital oracle: folded_forward_exact hidden flow + votes_fused."""
    h = x
    for layer in folded[:-1]:
        y = h @ jnp.asarray(layer.weights_pm1.T, jnp.float32) + jnp.asarray(
            layer.c, jnp.float32
        )
        h = jnp.where(y >= 0, 1.0, -1.0)
    return ensemble.votes_fused(head, h)


@pytest.mark.parametrize("bank", sorted(BANK_NETS))
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_pipeline_bit_exact_vs_oracle(bank, impl):
    sizes = BANK_NETS[bank]
    bias = BANK_BIAS[bank]
    rows, width = (int(s) for s in bank.split("x"))
    # the head really does land on this logical configuration
    assert pick_bank_config(sizes[1] + bias).width == width

    folded = _random_folded(sizes, seed=sum(map(ord, bank)), bias_cells=bias)
    ecfg = ensemble.EnsembleConfig(bias_cells=bias)
    pipe = pipeline.compile_pipeline(folded, ecfg, impl=impl, bq=16)
    x = jnp.asarray(
        np.random.default_rng(1).choice([-1.0, 1.0], (23, sizes[0])),
        jnp.float32,
    )
    want = np.asarray(_oracle_votes(folded, pipe.head, x))
    got = np.asarray(pipe.votes(x))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_pipeline_three_hidden_layers(impl):
    folded = _random_folded((120, 96, 64, 33, 7), seed=5, bias_cells=64)
    ecfg = ensemble.EnsembleConfig()
    pipe = pipeline.compile_pipeline(folded, ecfg, impl=impl, bq=8)
    x = jnp.asarray(
        np.random.default_rng(2).choice([-1.0, 1.0], (11, 120)), jnp.float32
    )
    want = np.asarray(_oracle_votes(folded, pipe.head, x))
    np.testing.assert_array_equal(np.asarray(pipe.votes(x)), want)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_pipeline_head_only(impl):
    """Degenerate pipeline (no hidden layers) == votes_fused on the head."""
    folded = _random_folded((128, 10), seed=9, bias_cells=64)
    ecfg = ensemble.EnsembleConfig()
    pipe = pipeline.compile_pipeline(folded, ecfg, impl=impl, bq=16)
    x = jnp.asarray(
        np.random.default_rng(3).choice([-1.0, 1.0], (9, 128)), jnp.float32
    )
    want = np.asarray(ensemble.votes_fused(pipe.head, x))
    np.testing.assert_array_equal(np.asarray(pipe.votes(x)), want)


def test_pipeline_matches_votes_faithful_noiseless():
    """Fused pipeline == the 33-sequential-search silicon flow (noiseless)."""
    folded = _random_folded((784, 128, 10), seed=11, bias_cells=64)
    ecfg = ensemble.EnsembleConfig()
    pipe = pipeline.compile_pipeline(folded, ecfg, impl="xla")
    x = np.random.default_rng(4).choice([-1.0, 1.0], (17, 784))
    x = jnp.asarray(x, jnp.float32)
    # hidden flow via the digital oracle, head via the faithful sweep
    h = x
    for layer in folded[:-1]:
        y = h @ jnp.asarray(layer.weights_pm1.T, jnp.float32) + jnp.asarray(
            layer.c, jnp.float32
        )
        h = jnp.where(y >= 0, 1.0, -1.0)
    want = np.asarray(ensemble.votes_faithful(pipe.head, h))
    np.testing.assert_array_equal(np.asarray(pipe.votes(x)), want)


def test_pipeline_batch_bucketing():
    """Ragged batch sizes pad to power-of-two buckets; results unaffected."""
    folded = _random_folded((100, 48, 6), seed=13, bias_cells=64)
    pipe = pipeline.compile_pipeline(
        folded, ensemble.EnsembleConfig(), impl="xla", min_bucket=32
    )
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.choice([-1.0, 1.0], (70, 100)), jnp.float32)
    full = np.asarray(pipe.votes(x))
    for b in (1, 31, 32, 33, 70):
        np.testing.assert_array_equal(np.asarray(pipe.votes(x[:b])), full[:b])
    assert pipeline.next_bucket(33, 32) == 64
    assert pipeline.next_bucket(32, 32) == 32
    assert pipeline.next_bucket(1, 32) == 32


def test_next_bucket_max_bucket_boundaries():
    """Boundary behavior at the serving cap: n == max_bucket passes, one
    more fails loudly, and a non-power-of-two cap rejects any n whose
    bucket overshoots it (even with n < max_bucket)."""
    assert pipeline.next_bucket(64, 64, max_bucket=64) == 64
    assert pipeline.next_bucket(1, 64, max_bucket=64) == 64
    assert pipeline.next_bucket(128, 64, max_bucket=128) == 128
    with pytest.raises(ValueError, match="max_bucket"):
        pipeline.next_bucket(65, 64, max_bucket=64)
    with pytest.raises(ValueError, match="max_bucket"):
        pipeline.next_bucket(129, 64, max_bucket=128)
    # a non-power-of-two cap: 70 buckets to 128 > 100 -> reject
    with pytest.raises(ValueError, match="max_bucket"):
        pipeline.next_bucket(70, 64, max_bucket=100)
    assert pipeline.next_bucket(60, 64, max_bucket=100) == 64
    # empty batches still rejected regardless of cap
    with pytest.raises(ValueError, match=">= 1"):
        pipeline.next_bucket(0, 64, max_bucket=64)


def test_pack_unpack_roundtrip_multidim():
    """pack_bits/unpack_bits round-trip with multi-dim leading axes, and
    the dot-product fast path matches the shift-broadcast reference."""
    rng = np.random.default_rng(7)
    for shape in [(3, 5, 77), (2, 2, 2, 33), (4, 31), (1, 1, 1, 256), (6,)]:
        bits = rng.integers(0, 2, shape).astype(np.uint8)
        packed = binarize.pack_bits(jnp.asarray(bits))
        assert packed.shape == (
            *shape[:-1], binarize.packed_width(shape[-1])
        )
        np.testing.assert_array_equal(
            np.asarray(packed),
            np.asarray(binarize.pack_bits_reference(jnp.asarray(bits))),
        )
        np.testing.assert_array_equal(
            np.asarray(binarize.unpack_bits(packed, shape[-1])), bits
        )


def test_fold_emits_dead_zone_free_constants():
    """fold's C_j has parity opposite n_in: sign(y + C) never sees zero."""
    cfg = bnn.MLPConfig(layer_sizes=(784, 64, 10), bias_cells=64)
    params = bnn.init_params(jax.random.PRNGKey(0), cfg)
    # perturb BN so C_j is nontrivial
    for i, layer in enumerate(params["layers"]):
        k = jax.random.PRNGKey(i + 1)
        layer["beta"] = jax.random.normal(k, layer["beta"].shape) * 3
        layer["mean"] = jax.random.normal(k, layer["mean"].shape) * 5
    folded = bnn.fold(params, cfg)
    for layer in folded:
        assert ((layer.c + layer.n_in) % 2 == 1).all(), layer.c
        assert (np.abs(layer.c) <= cfg.bias_cells).all()


@pytest.mark.parametrize("bank", sorted(BANK_NETS))
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_noisy_pipeline_noiseless_limit_bit_exact(bank, impl):
    """sigma -> 0 limit: every silicon-mode entry point (votes(key=),
    votes_mc, cum_votes) equals the PR-1 noiseless oracle bit-for-bit on
    all three bank configurations."""
    from repro.core.device_model import NOISELESS

    sizes, bias = BANK_NETS[bank], BANK_BIAS[bank]
    folded = _random_folded(sizes, seed=sum(map(ord, bank)), bias_cells=bias)
    ecfg = ensemble.EnsembleConfig(bias_cells=bias)
    pipe = pipeline.compile_pipeline(
        folded, ecfg, impl=impl, bq=16, noise=NOISELESS
    )
    x = jnp.asarray(
        np.random.default_rng(8).choice([-1.0, 1.0], (19, sizes[0])),
        jnp.float32,
    )
    key = jax.random.PRNGKey(42)
    want = np.asarray(_oracle_votes(folded, pipe.head, x))
    np.testing.assert_array_equal(np.asarray(pipe.votes(x, key)), want)
    mc = np.asarray(pipe.votes_mc(x, key, 3))
    np.testing.assert_array_equal(mc, np.broadcast_to(want, mc.shape))
    cum = np.asarray(pipe.cum_votes(x, key))
    np.testing.assert_array_equal(cum[-1], want)
    np.testing.assert_array_equal(
        cum,
        np.asarray(ensemble.sweep_from_votes(jnp.asarray(want),
                                             cum.shape[0])),
    )


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_noisy_pipeline_impls_agree_under_silicon(impl):
    """Same key => pallas and xla noisy twins produce identical votes
    (the sampled thresholds are computed outside the kernel), and a
    silicon draw actually differs from the noiseless votes."""
    from repro.core.device_model import SILICON

    folded = _random_folded((784, 128, 10), seed=23, bias_cells=64)
    ecfg = ensemble.EnsembleConfig()
    pipe = pipeline.compile_pipeline(
        folded, ecfg, impl=impl, bq=16, noise=SILICON
    )
    # batch == bucket so the in-program sample shape equals the logical
    # batch (the draw-for-draw comparison below needs identical shapes)
    x = jnp.asarray(
        np.random.default_rng(9).choice([-1.0, 1.0], (64, 784)), jnp.float32
    )
    key = jax.random.PRNGKey(5)
    got = np.asarray(pipe.votes(x, key))
    # silicon noise perturbs (vs noiseless) ...
    assert (got != np.asarray(pipe.votes(x))).any()
    # ... but both impls sample identically
    ref = pipeline.compile_pipeline(folded, ecfg, impl="xla", noise=SILICON)
    np.testing.assert_array_equal(got, np.asarray(ref.votes(x, key)))
    # and the noisy path is draw-for-draw equal to ensemble's fused twin
    h = x
    for layer in folded[:-1]:
        y = h @ jnp.asarray(layer.weights_pm1.T, jnp.float32) + jnp.asarray(
            layer.c, jnp.float32
        )
        h = jnp.where(y >= 0, 1.0, -1.0)
    want = np.asarray(ensemble.votes_fused_noisy(
        head=pipe.head, x_pm1=h, key=key, physics=pipe.physics))
    np.testing.assert_array_equal(got, want)


def test_pipeline_without_noise_rejects_key():
    folded = _random_folded((128, 10), seed=31, bias_cells=64)
    pipe = pipeline.compile_pipeline(folded, ensemble.EnsembleConfig(),
                                     impl="xla")
    x = jnp.asarray(
        np.random.default_rng(11).choice([-1.0, 1.0], (4, 128)), jnp.float32
    )
    with pytest.raises(ValueError, match="noise="):
        pipe.votes(x, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="noise="):
        pipe.votes_mc(x, jax.random.PRNGKey(0), 2)


def test_sweep_from_votes_matches_accuracy_sweep_cumsum():
    """The truncated-sweep recovery identity behind the fused Fig. 5 path."""
    folded = _random_folded((128, 10), seed=21, bias_cells=64)
    ecfg = ensemble.EnsembleConfig()
    head = ensemble.build_head(folded[-1], ecfg)
    x = binarize.random_pm1(jax.random.PRNGKey(2), (12, 128))
    from repro.core.cam import query_with_bias

    hd = head.cam.search_hd(query_with_bias(x, head.bias_cells))
    per_pass = np.asarray(
        (hd[None] <= head.thresholds[:, None, None]).astype(jnp.int32)
    )
    want = np.cumsum(per_pass, axis=0)
    votes = ensemble.votes_fused(head, x)
    got = np.asarray(ensemble.sweep_from_votes(votes, ecfg.n_passes))
    np.testing.assert_array_equal(got, want)

"""InferenceSpec + CompiledPipeline.run: the compiled-request redesign.

Three bars:
  * spec VALIDATION — every unsupported combination is a construction-
    time ValueError, including the previously-hidden `cum_votes`
    noiseless default-key case (now the explicit spec
    `InferenceSpec(noise="off", cumulative=True)`);
  * run() SEMANTICS — bit-exact against the same digital oracles the
    legacy eight-method family is tested against, across the macro's
    three logical bank configurations, plus centralized key/keys
    validation and per-spec program caching;
  * BUCKETING properties — hypothesis property tests for
    `next_bucket` / `bucket_grid` (grid membership, monotonicity,
    max_bucket caps), via the tests/_hypothesis_compat.py guard.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    from _hypothesis_compat import given, settings, st

    HAVE_HYPOTHESIS = False

settings.register_profile("ci", max_examples=100, deadline=None)
settings.load_profile("ci")

from repro import pipeline
from repro.core import bnn, ensemble
from repro.core.device_model import NOISELESS, SILICON
from repro.spec import InferenceSpec, legacy_entry_spec

BANK_NETS = {
    "512x256": (300, 192, 12),
    "1024x128": (784, 64, 10),
    "2048x64": (96, 32, 5),
}
BANK_BIAS = {"512x256": 64, "1024x128": 64, "2048x64": 32}


def _random_folded(sizes, seed, bias_cells):
    rng = np.random.default_rng(seed)
    layers = []
    for i in range(len(sizes) - 1):
        n_in, n_out = sizes[i], sizes[i + 1]
        c = bnn.parity_adjust_c(
            rng.integers(-bias_cells, bias_cells + 1, n_out), n_in, bias_cells
        )
        layers.append(bnn.FoldedLayer(
            weights_pm1=rng.choice([-1, 1], (n_out, n_in)).astype(np.int8),
            c=c,
        ))
    return layers


def _make_pipe(bank, noise=None, **kw):
    sizes, bias = BANK_NETS[bank], BANK_BIAS[bank]
    folded = _random_folded(sizes, seed=sum(map(ord, bank)), bias_cells=bias)
    pipe = pipeline.compile_pipeline(
        folded, ensemble.EnsembleConfig(bias_cells=bias), impl="xla",
        min_bucket=8, noise=noise, **kw
    )
    return pipe, folded, sizes


def _oracle_votes(folded, head, x):
    h = x
    for layer in folded[:-1]:
        y = h @ jnp.asarray(layer.weights_pm1.T, jnp.float32) + jnp.asarray(
            layer.c, jnp.float32
        )
        h = jnp.where(y >= 0, 1.0, -1.0)
    return ensemble.votes_fused(head, h)


def _images(n, n_in, seed=1):
    rng = np.random.default_rng(seed)
    return rng.choice([-1.0, 1.0], (n, n_in)).astype(np.float32)


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------
def test_spec_defaults_and_derived_contract():
    s = InferenceSpec()
    assert (s.noise, s.mc_samples, s.reduction, s.cumulative) == \
        ("off", None, "none", False)
    assert not s.needs_physics and not s.needs_key and not s.needs_keys
    assert s.batch_axis == 0
    assert InferenceSpec(noise="batch").needs_key
    assert InferenceSpec(noise="per_request").needs_keys
    # leading samples / passes axes shift the batch axis
    assert InferenceSpec(noise="batch", mc_samples=4).batch_axis == 1
    assert InferenceSpec(cumulative=True).batch_axis == 1
    assert InferenceSpec(noise="per_request", mc_samples=4,
                         reduction="sum").batch_axis == 0
    assert InferenceSpec(reduction="argmax").batch_axis == 0
    # hashable values: usable as program-cache / warmup-report keys
    assert InferenceSpec() in {InferenceSpec()}
    assert "noise=batch" in InferenceSpec(noise="batch").describe()


@pytest.mark.parametrize("bad", [
    dict(noise="nope"),
    dict(reduction="mean"),
    dict(mc_samples=0, noise="batch"),
    dict(mc_samples=4),  # MC over a deterministic compare
    dict(reduction="sum"),  # nothing to sum without MC
    dict(noise="batch", mc_samples=4, reduction="argmax"),
    dict(cumulative=True, noise="batch", mc_samples=4),
    dict(cumulative=True, reduction="argmax"),
    dict(cumulative=True, noise="per_request"),
])
def test_spec_rejects_unsupported_combinations(bad):
    with pytest.raises(ValueError):
        InferenceSpec(**bad)


def test_legacy_entry_mapping():
    assert legacy_entry_spec("votes") == InferenceSpec()
    assert legacy_entry_spec("votes_noisy") == InferenceSpec(noise="batch")
    assert legacy_entry_spec("votes_mc", 8) == \
        InferenceSpec(noise="batch", mc_samples=8)
    assert legacy_entry_spec("votes_mc_each_sum", 8) == InferenceSpec(
        noise="per_request", mc_samples=8, reduction="sum")
    assert legacy_entry_spec("cum_votes") == \
        InferenceSpec(noise="batch", cumulative=True)
    assert legacy_entry_spec("predict_each") == \
        InferenceSpec(noise="per_request", reduction="argmax")
    with pytest.raises(ValueError, match="mc_samples"):
        legacy_entry_spec("votes_mc")
    with pytest.raises(ValueError, match="no mc_samples"):
        legacy_entry_spec("votes", 4)
    with pytest.raises(ValueError, match="unknown legacy entry"):
        legacy_entry_spec("votes_v2")


# ---------------------------------------------------------------------------
# run() semantics vs the digital oracles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bank", sorted(BANK_NETS))
def test_run_noiseless_specs_bit_exact(bank):
    pipe, folded, sizes = _make_pipe(bank)
    x = jnp.asarray(_images(23, sizes[0]))
    want = np.asarray(_oracle_votes(folded, pipe.head, x))
    got = np.asarray(pipe.run(x, InferenceSpec()))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        np.asarray(pipe.run(x, InferenceSpec(reduction="argmax"))),
        want.argmax(-1),
    )
    # the EXPLICIT noiseless staircase: valid without any physics at all
    # (this used to be cum_votes silently substituting PRNGKey(0), and
    # only on noise=NOISELESS-compiled pipelines)
    cum = np.asarray(pipe.run(x, InferenceSpec(cumulative=True)))
    np.testing.assert_array_equal(cum[-1], want)
    np.testing.assert_array_equal(
        cum,
        np.asarray(ensemble.sweep_from_votes(jnp.asarray(want),
                                             cum.shape[0])),
    )


@pytest.mark.parametrize("bank", sorted(BANK_NETS))
def test_run_silicon_specs_noiseless_limit(bank):
    """Every noisy spec's sigma->0 limit equals the noiseless oracle."""
    pipe, folded, sizes = _make_pipe(bank, noise=NOISELESS)
    x = jnp.asarray(_images(19, sizes[0], seed=8))
    key = jax.random.PRNGKey(42)
    keys = jnp.asarray(jax.random.split(key, x.shape[0]))
    want = np.asarray(_oracle_votes(folded, pipe.head, x))
    np.testing.assert_array_equal(
        np.asarray(pipe.run(x, InferenceSpec(noise="batch"), key=key)), want
    )
    np.testing.assert_array_equal(
        np.asarray(pipe.run(x, InferenceSpec(noise="per_request"),
                            keys=keys)),
        want,
    )
    mc = np.asarray(pipe.run(
        x, InferenceSpec(noise="batch", mc_samples=3), key=key
    ))
    np.testing.assert_array_equal(mc, np.broadcast_to(want, mc.shape))
    np.testing.assert_array_equal(
        np.asarray(pipe.run(
            x,
            InferenceSpec(noise="per_request", mc_samples=3,
                          reduction="sum"),
            keys=keys,
        )),
        want * 3,
    )
    cum = np.asarray(pipe.run(
        x, InferenceSpec(noise="batch", cumulative=True), key=key
    ))
    np.testing.assert_array_equal(cum[-1], want)


def test_run_silicon_draw_matches_fused_twin():
    """One batch draw through run() is draw-for-draw the ensemble twin."""
    pipe, folded, sizes = _make_pipe("1024x128", noise=SILICON)
    x = jnp.asarray(_images(16, sizes[0], seed=9))
    key = jax.random.PRNGKey(5)
    # batch == bucket so in-program sample shape == logical batch
    x = jnp.pad(x, ((0, 0), (0, 0)))[:16]
    got = np.asarray(pipe.run(x, InferenceSpec(noise="batch"), key=key))
    h = x
    for layer in folded[:-1]:
        y = h @ jnp.asarray(layer.weights_pm1.T, jnp.float32) + jnp.asarray(
            layer.c, jnp.float32
        )
        h = jnp.where(y >= 0, 1.0, -1.0)
    want = np.asarray(ensemble.votes_fused_noisy(
        head=pipe.head, x_pm1=h, key=key, physics=pipe.physics))
    np.testing.assert_array_equal(got, want)
    # a real draw differs from the deterministic spec
    assert (got != np.asarray(pipe.run(x, InferenceSpec()))).any()


def test_run_key_and_keys_validation():
    pipe, _folded, sizes = _make_pipe("2048x64", noise=SILICON)
    npipe, _f, _s = _make_pipe("2048x64")
    x = _images(5, sizes[0])
    key = jax.random.PRNGKey(0)
    keys = np.asarray(jax.random.split(key, 5))
    # deterministic spec takes no randomness
    with pytest.raises(ValueError, match="neither key= nor keys="):
        pipe.run(x, InferenceSpec(), key=key)
    # batch spec: key required, keys rejected
    with pytest.raises(ValueError, match="explicit key="):
        pipe.run(x, InferenceSpec(noise="batch"))
    with pytest.raises(ValueError, match="not per-request keys="):
        pipe.run(x, InferenceSpec(noise="batch"), keys=keys)
    # per-request spec: keys required (right shape), key rejected
    with pytest.raises(ValueError, match="needs per-request keys="):
        pipe.run(x, InferenceSpec(noise="per_request"))
    with pytest.raises(ValueError, match="not a batch-level key="):
        pipe.run(x, InferenceSpec(noise="per_request"), key=key, keys=keys)
    with pytest.raises(ValueError, match="keys must be"):
        pipe.run(x, InferenceSpec(noise="per_request"), keys=keys[:3])
    # physics-requiring specs fail loudly on a noiseless-compiled pipeline
    with pytest.raises(ValueError, match="noise="):
        npipe.run(x, InferenceSpec(noise="batch"), key=key)
    with pytest.raises(ValueError, match="noise="):
        npipe.warmup(8, specs=(InferenceSpec(noise="per_request"),))


def test_cum_votes_shim_explicit_key_contract():
    """The satellite fix: no hidden PRNGKey(0) substitution anywhere."""
    # noisy pipeline: key=None must still fail loudly
    si, _f, sizes = _make_pipe("2048x64", noise=SILICON)
    x = _images(4, sizes[0])
    pipeline._LEGACY_WARNED.discard("cum_votes")  # warn-once is per-process
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="explicit key"):
            si.cum_votes(x)
    # NOISELESS-physics pipeline: key=None now routes through the
    # explicit deterministic spec — same staircase, no fake key
    nl, folded, _ = _make_pipe("2048x64", noise=NOISELESS)
    want = np.asarray(nl.run(x, InferenceSpec(cumulative=True)))
    got = np.asarray(nl.cum_votes(x))
    np.testing.assert_array_equal(got, want)
    # and a pipeline with NO physics at all supports the staircase too
    plain, _f2, _s2 = _make_pipe("2048x64")
    np.testing.assert_array_equal(
        np.asarray(plain.cum_votes(x)),
        np.asarray(plain.run(x, InferenceSpec(cumulative=True))),
    )


def test_program_cache_one_program_per_spec():
    pipe, _folded, sizes = _make_pipe("2048x64", noise=SILICON)
    s1 = InferenceSpec(noise="per_request")
    s2 = InferenceSpec(noise="per_request", mc_samples=2)
    p1 = pipe.program(s1)
    assert pipe.program(s1) is p1  # cache hit: the SAME compiled program
    assert pipe.program(InferenceSpec(noise="per_request")) is p1
    assert pipe.program(s2) is not p1  # distinct spec -> distinct program
    assert set(pipe._programs) == {s1, s2}


def test_run_bucketing_invariance_across_specs():
    """Padding to a bucket never changes trimmed results, whatever the
    spec's output layout (leading batch, samples-first, passes-first)."""
    pipe, _folded, sizes = _make_pipe("2048x64", noise=NOISELESS)
    x = _images(21, sizes[0], seed=3)
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(1), 21))
    key = jax.random.PRNGKey(2)
    cases = [
        (InferenceSpec(), {}),
        (InferenceSpec(reduction="argmax"), {}),
        (InferenceSpec(cumulative=True), {}),
        (InferenceSpec(noise="batch", mc_samples=2), dict(key=key)),
        (InferenceSpec(noise="per_request"), dict(keys=keys)),
        (InferenceSpec(noise="per_request", mc_samples=2,
                       reduction="sum"), dict(keys=keys)),
    ]
    for spec, kw in cases:
        full = np.asarray(pipe.run(x, spec, **kw))
        ax = spec.batch_axis
        assert full.shape[ax] == 21, (spec, full.shape)
        for b in (1, 8, 13):
            sub_kw = {
                k: (v[:b] if k == "keys" else v) for k, v in kw.items()
            }
            part = np.asarray(pipe.run(x[:b], spec, **sub_kw))
            if spec.noise == "batch":
                # batch-shaped draws are composition-dependent by
                # construction — only shapes are checked
                assert part.shape[ax] == b
            else:
                np.testing.assert_array_equal(
                    part, full[:b] if ax == 0 else full[:, :b]
                )


# ---------------------------------------------------------------------------
# spec-driven warmup
# ---------------------------------------------------------------------------
def test_warmup_reports_per_spec_bucket_and_cache_is_free():
    pipe, _folded, sizes = _make_pipe("2048x64", noise=SILICON,
                                      max_bucket=32)
    specs = (InferenceSpec(noise="per_request"),
             InferenceSpec(noise="per_request", mc_samples=2,
                           reduction="sum"))
    times = pipe.warmup(32, specs=specs)
    assert set(times) == {(s, b) for s in specs for b in (8, 16, 32)}
    assert all(t > 0 for t in times.values())
    # every program is now cached: warming again hits the jit cache and
    # must be far cheaper than the compile pass
    progs = {s: pipe.program(s) for s in specs}
    again = pipe.warmup(32, specs=specs)
    assert set(again) == set(times)
    assert all(pipe.program(s) is p for s, p in progs.items())
    assert sum(again.values()) < 0.5 * sum(times.values())


def test_warmup_defaults_and_legacy_entries():
    pipe, _folded, sizes = _make_pipe("2048x64", max_bucket=16)
    times = pipe.warmup(16)
    assert set(times) == {(InferenceSpec(), 8), (InferenceSpec(), 16)}
    si, _f, _s = _make_pipe("2048x64", noise=SILICON, max_bucket=8)
    pipeline._LEGACY_WARNED.discard("warmup(entries=)")
    with pytest.warns(DeprecationWarning):
        t2 = si.warmup(8, entries=("votes", "votes_mc"), mc_samples=2)
    assert set(t2) == {
        (InferenceSpec(), 8),
        (InferenceSpec(noise="batch", mc_samples=2), 8),
    }
    with pytest.raises(ValueError, match="unknown warmup entries"):
        si.warmup(8, entries=("votes_v2",))
    with pytest.raises(ValueError, match="not both"):
        si.warmup(8, specs=(InferenceSpec(),), entries=("votes",))


# ---------------------------------------------------------------------------
# next_bucket / bucket_grid property tests (hypothesis-guarded)
# ---------------------------------------------------------------------------
@given(
    n=st.integers(min_value=1, max_value=4096),
    max_batch=st.integers(min_value=1, max_value=4096),
    min_bucket=st.sampled_from([1, 2, 8, 32, 64, 48]),
)
def test_next_bucket_lands_on_grid(n, max_batch, min_bucket):
    """Every batch 1..max_batch dispatches into a bucket_grid bucket."""
    if n > max_batch:
        n = 1 + n % max_batch
    grid = pipeline.bucket_grid(max_batch, min_bucket)
    b = pipeline.next_bucket(n, min_bucket)
    assert b in grid
    assert b >= n or b == min_bucket
    # grid is the doubling chain from min_bucket covering max_batch
    assert grid[0] == min_bucket and grid[-1] >= max_batch
    assert all(y == 2 * x for x, y in zip(grid, grid[1:]))


@given(
    n=st.integers(min_value=1, max_value=4095),
    min_bucket=st.sampled_from([1, 4, 8, 64]),
)
def test_next_bucket_monotone(n, min_bucket):
    """Buckets are monotone in n (never shrink as the batch grows)."""
    assert (pipeline.next_bucket(n, min_bucket)
            <= pipeline.next_bucket(n + 1, min_bucket))


@given(
    n=st.integers(min_value=1, max_value=4096),
    min_bucket=st.sampled_from([1, 8, 64]),
    cap_pow=st.integers(min_value=0, max_value=7),
)
def test_next_bucket_respects_max_bucket(n, min_bucket, cap_pow):
    """With a cap: either the result is <= cap, or it raises loudly —
    exactly when the uncapped bucket would overshoot."""
    cap = min_bucket * (2 ** cap_pow)
    uncapped = pipeline.next_bucket(n, min_bucket)
    if uncapped <= cap:
        assert pipeline.next_bucket(n, min_bucket, max_bucket=cap) \
            == uncapped
    else:
        with pytest.raises(ValueError, match="max_bucket"):
            pipeline.next_bucket(n, min_bucket, max_bucket=cap)


def test_bucket_property_fallbacks_plain():
    """Plain (non-hypothesis) slice of the same properties, so the
    contract is exercised even where hypothesis is not installed."""
    for min_bucket in (1, 8, 48, 64):
        grid = pipeline.bucket_grid(1000, min_bucket)
        prev = 0
        for n in (1, 2, 7, 8, 9, 63, 64, 65, 500, 1000):
            b = pipeline.next_bucket(n, min_bucket)
            assert b in grid and b >= min(n, b)
            assert b >= prev
            prev = b
    with pytest.raises(ValueError, match="max_bucket"):
        pipeline.next_bucket(65, 64, max_bucket=64)
    assert pipeline.next_bucket(64, 64, max_bucket=64) == 64

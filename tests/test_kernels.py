"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels execute in interpret mode on CPU (same semantics as Mosaic/TPU);
every cell asserts exact equality — these are integer kernels, allclose
means equal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binarize
from repro.kernels import ops, ref


def _pack(rng, n, k):
    bits = rng.integers(0, 2, (n, k)).astype(np.uint8)
    return binarize.pack_bits(jnp.asarray(bits))


SHAPES = [
    (1, 1, 32),
    (8, 10, 192),
    (33, 7, 64),
    (130, 70, 300),
    (64, 129, 1000),
    (256, 256, 512),
]


@pytest.mark.parametrize("m,n,k", SHAPES)
def test_binary_gemm_vs_ref(m, n, k):
    rng = np.random.default_rng(m * 1000 + n)
    x, w = _pack(rng, m, k), _pack(rng, n, k)
    got = ops.binary_gemm_hd(x, w, bm=32, bn=32, chunk=4)
    want = ref.binary_gemm_hd_ref(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bm,bn,chunk", [(8, 8, 1), (16, 32, 2), (64, 64, 8)])
def test_binary_gemm_block_shapes(bm, bn, chunk):
    rng = np.random.default_rng(7)
    x, w = _pack(rng, 50, 257), _pack(rng, 41, 257)
    got = ops.binary_gemm_hd(x, w, bm=bm, bn=bn, chunk=chunk)
    want = ref.binary_gemm_hd_ref(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,n,k", SHAPES[:4])
def test_binary_gemm_dot_identity(m, n, k):
    rng = np.random.default_rng(3)
    xb = rng.integers(0, 2, (m, k)).astype(np.uint8)
    wb = rng.integers(0, 2, (n, k)).astype(np.uint8)
    dot = ops.binary_gemm_dot(
        binarize.pack_bits(jnp.asarray(xb)),
        binarize.pack_bits(jnp.asarray(wb)),
        k, bm=32, bn=32, chunk=4,
    )
    dense = (2.0 * xb - 1) @ (2.0 * wb - 1).T
    np.testing.assert_array_equal(np.asarray(dot), dense.astype(np.int64))


@pytest.mark.parametrize("b,c,k,p", [
    (1, 1, 32, 1), (16, 10, 192, 33), (40, 20, 4160, 33), (7, 129, 96, 5),
])
def test_cam_vote_vs_ref(b, c, k, p):
    rng = np.random.default_rng(b * 17 + c)
    q, rows = _pack(rng, b, k), _pack(rng, c, k)
    thr = jnp.asarray(
        rng.integers(0, k + 1, p).astype(np.int32)
    )
    got = ops.cam_vote(q, rows, thr, bq=16, bc=16, chunk=4)
    want = ref.cam_vote_ref(q, rows, thr)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cam_vote_sampled_thresholds_vs_ref():
    """The silicon-noise operand path: a [B, C, P] float32 block of
    physics-sampled thresholds replaces the shared schedule; HD is still
    computed once.  Against the dense jnp compare, and bit-equal to the
    schedule path when the samples ARE the (broadcast) schedule."""
    rng = np.random.default_rng(41)
    b, c, k, p = 21, 13, 192, 9
    q, rows = _pack(rng, b, k), _pack(rng, c, k)
    thr = jnp.asarray(rng.integers(0, k + 1, p).astype(np.int32))
    samples = jnp.asarray(
        rng.normal(k / 2, 8.0, (b, c, p)).astype(np.float32))
    got = ops.cam_vote(q, rows, thr, bq=16, bc=16, chunk=4,
                       thr_samples=samples)
    hd = np.asarray(ref.binary_gemm_hd_ref(q, rows)).astype(np.float32)
    want = (hd[:, :, None] <= np.asarray(samples)).sum(-1)
    np.testing.assert_array_equal(np.asarray(got), want)
    base = jnp.broadcast_to(
        thr.astype(jnp.float32)[None, None, :], (b, c, p))
    np.testing.assert_array_equal(
        np.asarray(ops.cam_vote(q, rows, thr, bq=16, bc=16, chunk=4,
                                thr_samples=base)),
        np.asarray(ops.cam_vote(q, rows, thr, bq=16, bc=16, chunk=4)),
    )


def test_mxu_path_matches_packed_path():
    rng = np.random.default_rng(0)
    xb = rng.integers(0, 2, (24, 160)).astype(np.uint8)
    wb = rng.integers(0, 2, (12, 160)).astype(np.uint8)
    hd = ops.binary_gemm_hd(
        binarize.pack_bits(jnp.asarray(xb)),
        binarize.pack_bits(jnp.asarray(wb)), bm=8, bn=8, chunk=1,
    )
    mxu = ops.binary_gemm_mxu(
        jnp.asarray(2.0 * xb - 1), jnp.asarray((2.0 * wb - 1).T)
    )
    np.testing.assert_array_equal(np.asarray(mxu), 160 - 2 * np.asarray(hd))


def test_kernel_dtype_of_results():
    rng = np.random.default_rng(0)
    q, rows = _pack(rng, 4, 64), _pack(rng, 4, 64)
    assert ops.binary_gemm_hd(q, rows).dtype == jnp.int32
    assert ops.cam_vote(q, rows, jnp.arange(3, dtype=jnp.int32)).dtype == jnp.int32

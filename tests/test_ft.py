"""Fault tolerance: supervisor restart, straggler detection, determinism."""

import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step
from repro.ft import (
    InjectedFailure,
    StragglerMonitor,
    Supervisor,
    SupervisorConfig,
    failing_step,
    rescale_microbatches,
    slow_step,
)


def _toy_problem():
    """Deterministic least-squares toy: state is a weight vector."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(32,)).astype(np.float32))

    @jax.jit
    def step(state, batch):
        w = state["w"]
        g = A.T @ (A @ w - b) / 32 + batch["noise"] * 0.0
        w = w - 0.1 * g
        loss = 0.5 * jnp.mean((A @ w - b) ** 2)
        return {"w": w}, {"loss": loss}

    def make_data(start):
        def gen():
            s = start
            while True:
                yield {"noise": jnp.float32(s)}
                s += 1
        return gen()

    init = {"w": jnp.zeros(8)}
    return step, make_data, init


def _run(tmp_path, step_fn, make_data, init, n_steps, **cfg_kw):
    cfg = SupervisorConfig(
        ckpt_dir=tmp_path, ckpt_every=5, backoff_s=0.0, **cfg_kw
    )
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), init
    )
    sup = Supervisor(cfg, step_fn, make_data, template)
    state = sup.run(init, n_steps)
    return sup, state


def test_supervisor_completes_without_failures(tmp_path):
    step, data, init = _toy_problem()
    sup, state = _run(tmp_path, step, data, init, 20)
    assert len(sup.history) == 20
    assert sup.history[-1]["loss"] < sup.history[0]["loss"]


def test_supervisor_survives_injected_failures(tmp_path):
    step, data, init = _toy_problem()
    flaky = failing_step(step, fail_at=[7, 13])
    sup, state = _run(tmp_path, flaky, data, init, 25)
    assert sup.restarts == 2
    steps_run = [h["step"] for h in sup.history]
    assert steps_run[-1] == 24
    # every step 0..24 executed at least once (replay covers the gaps)
    assert set(range(25)).issubset(set(steps_run))
    assert latest_step(tmp_path) is not None


def test_supervisor_result_matches_failure_free_run(tmp_path):
    """Checkpoint/restart + deterministic data replay => same final state."""
    step, data, init = _toy_problem()
    _, clean = _run(tmp_path / "clean", step, data, init, 25)
    flaky = failing_step(step, fail_at=[11])
    _, faulted = _run(tmp_path / "flaky", flaky, data, init, 25)
    np.testing.assert_allclose(
        np.asarray(clean["w"]), np.asarray(faulted["w"]), atol=1e-6
    )


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    step, data, init = _toy_problem()
    always = failing_step(step, fail_at=range(0, 1000))
    cfg = SupervisorConfig(ckpt_dir=tmp_path, ckpt_every=5,
                           max_restarts=3, backoff_s=0.0)
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), init
    )
    sup = Supervisor(cfg, always, data, template)
    with pytest.raises(InjectedFailure):
        sup.run(init, 10)
    assert sup.restarts == 4


def test_straggler_monitor_fires_on_sustained_outliers():
    m = StragglerMonitor(alpha=0.2, z=3.0, patience=2)
    for s in range(20):
        m.observe(s, 0.10 + 0.001 * (s % 3))
    fired = []
    for s in range(20, 26):
        if m.observe(s, 0.50):
            fired.append(s)
    assert fired, "sustained 5x slowdown must alert"


def test_straggler_monitor_ignores_single_blip():
    m = StragglerMonitor(alpha=0.2, z=3.0, patience=3)
    for s in range(20):
        m.observe(s, 0.1)
    assert not m.observe(20, 0.5)
    assert not m.observe(21, 0.1)
    assert m.strikes == 0


def test_heartbeat_written(tmp_path):
    step, data, init = _toy_problem()
    hb = tmp_path / "heartbeat.json"
    sup, _ = _run(tmp_path, step, data, init, 5, heartbeat=hb)
    import json

    assert json.loads(hb.read_text())["step"] == 4


def test_rescale_microbatches():
    # 2 pods (dp=32) with mb=2 -> 1 pod (dp=16): mb doubles
    assert rescale_microbatches(256, 32, 16, 2) == 4
    # scale up halves accumulation
    assert rescale_microbatches(256, 16, 32, 4) == 2

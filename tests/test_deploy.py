"""Deployment artifact: construction, persistence, serving from disk.

The correctness bar is the ISSUE-5 acceptance line: `Deployment.save`
-> `load` -> `run` round-trips BIT-EXACTLY — on all three logical bank
configurations of the macro AND a conv config, for the noiseless spec
and the per-request-key silicon spec — and `serve.picbnn` registers
models from a live Deployment, and from a checkpoint directory, serving
the same bits either way.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bnn, convnet, ensemble
from repro.core.binarize import InputEncoding
from repro.core.convnet import CNNConfig, ConvSpec
from repro.core.device_model import NOISELESS, SILICON
from repro.deploy import COMPILE_OPTIONS, Deployment, deploy, is_deployment_dir
from repro.serve.picbnn import BatchingPolicy, PicBnnServer
from repro.spec import InferenceSpec

BANK_NETS = {
    "512x256": (300, 192, 12),
    "1024x128": (784, 64, 10),
    "2048x64": (96, 32, 5),
}
BANK_BIAS = {"512x256": 64, "1024x128": 64, "2048x64": 32}

#: small end-to-end-binary CNN (12x12 input) — fast but exercises the
#: conv prefix, thermometer encoding, and positionwise FC repack
TINY_CNN = CNNConfig(
    side=12,
    encoding=InputEncoding("thermometer", 4),
    conv=(ConvSpec(3, 32, 2),),
    hidden=(64,),
    n_classes=5,
    bias_cells=64,
)

VOTES = InferenceSpec()
EACH = InferenceSpec(noise="per_request")


def _random_folded(sizes, seed, bias_cells):
    rng = np.random.default_rng(seed)
    layers = []
    for i in range(len(sizes) - 1):
        n_in, n_out = sizes[i], sizes[i + 1]
        c = bnn.parity_adjust_c(
            rng.integers(-bias_cells, bias_cells + 1, n_out), n_in, bias_cells
        )
        layers.append(bnn.FoldedLayer(
            weights_pm1=rng.choice([-1, 1], (n_out, n_in)).astype(np.int8),
            c=c,
        ))
    return layers


def _mlp_deployment(bank, noise=None, **opts):
    sizes, bias = BANK_NETS[bank], BANK_BIAS[bank]
    folded = _random_folded(sizes, seed=sum(map(ord, bank)), bias_cells=bias)
    return deploy(
        folded, ens_cfg=ensemble.EnsembleConfig(bias_cells=bias),
        noise=noise, impl="xla", min_bucket=8, **opts
    ), sizes


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------
def test_deploy_from_folded_and_layer_sizes():
    dep, sizes = _mlp_deployment("1024x128")
    assert dep.layer_sizes == sizes
    assert dep.conv_layers == ()
    pipe = dep.pipeline()
    assert pipe is dep.pipeline()  # compiled once, cached
    assert (pipe.n_in, pipe.n_classes) == (sizes[0], sizes[-1])


def test_deploy_from_trained_params_folds_here():
    cfg = bnn.MLPConfig(layer_sizes=(64, 32, 4), bias_cells=32)
    params = bnn.init_params(jax.random.PRNGKey(0), cfg)
    dep = deploy(params, config=cfg, impl="xla", min_bucket=8)
    # config supplies the ensemble bias cells; fold ran inside deploy()
    assert dep.ens_cfg.bias_cells == 32
    assert dep.layer_sizes == (64, 32, 4)
    want = deploy(bnn.fold(params, cfg), config=cfg, impl="xla",
                  min_bucket=8)
    x = np.random.default_rng(1).choice([-1.0, 1.0], (5, 64)).astype(
        np.float32)
    np.testing.assert_array_equal(
        np.asarray(dep.run(x, VOTES)), np.asarray(want.run(x, VOTES))
    )


def test_deploy_cnn_config_threads_geometry():
    folded = convnet.random_folded_cnn(TINY_CNN, seed=3)
    dep = deploy(folded, config=TINY_CNN, impl="xla", min_bucket=4)
    assert dep.image_side == TINY_CNN.side
    assert dep.image_encoding == TINY_CNN.encoding
    assert dep.layer_sizes is None  # conv graphs have no MLP topology
    assert len(dep.conv_layers) == 1
    pipe = dep.pipeline()
    assert pipe.n_in == TINY_CNN.side ** 2


def test_deploy_rejects_unknown_options_and_dict_without_config():
    folded = _random_folded((64, 4), seed=1, bias_cells=32)
    with pytest.raises(ValueError, match="unknown compile options"):
        deploy(folded, block_size=4)
    with pytest.raises(ValueError, match="config="):
        deploy({"layers": []})
    assert "impl" in COMPILE_OPTIONS


# ---------------------------------------------------------------------------
# save / load round trips (the acceptance bar)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bank", sorted(BANK_NETS))
def test_save_load_bit_exact_all_banks(bank, tmp_path):
    """Noiseless spec AND per-request silicon spec survive the disk
    round trip bit-for-bit, on every logical bank configuration."""
    dep, sizes = _mlp_deployment(bank, noise=SILICON)
    rng = np.random.default_rng(7)
    x = rng.choice([-1.0, 1.0], (13, sizes[0])).astype(np.float32)
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(11), 13))
    want_nl = np.asarray(dep.run(x, VOTES))
    want_si = np.asarray(dep.run(x, EACH, keys=keys))

    root = tmp_path / bank
    dep.save(root)
    assert is_deployment_dir(root)
    loaded = Deployment.load(root)
    assert loaded.noise == SILICON
    assert loaded.ens_cfg == dep.ens_cfg
    assert loaded.compile_options == dep.compile_options
    for orig, back in zip(dep.folded, loaded.folded):
        np.testing.assert_array_equal(orig.weights_pm1, back.weights_pm1)
        np.testing.assert_array_equal(orig.c, back.c)
    np.testing.assert_array_equal(np.asarray(loaded.run(x, VOTES)), want_nl)
    np.testing.assert_array_equal(
        np.asarray(loaded.run(x, EACH, keys=keys)), want_si
    )


@pytest.mark.parametrize("cfg_name", ["tiny", "mnist_cnn"])
def test_save_load_bit_exact_cnn(cfg_name, tmp_path):
    """The conv configs round-trip too: conv prefix (shapes + strides),
    input encoding, and image geometry all reconstruct from disk — on a
    fast tiny config AND the paper's MNIST CNN config."""
    if cfg_name == "mnist_cnn":
        from repro.configs.paper_cnn import MNIST_CNN as cfg
    else:
        cfg = TINY_CNN
    folded = convnet.random_folded_cnn(cfg, seed=5)
    dep = deploy(folded, config=cfg, noise=SILICON, impl="xla",
                 min_bucket=4)
    rng = np.random.default_rng(9)
    x = rng.random((6, cfg.n_in)).astype(np.float32)  # raw pixels
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(2), 6))
    want_nl = np.asarray(dep.run(x, VOTES))
    want_si = np.asarray(dep.run(x, EACH, keys=keys))

    dep.save(tmp_path / "cnn")
    loaded = Deployment.load(tmp_path / "cnn")
    assert loaded.image_side == cfg.side
    assert loaded.image_encoding == cfg.encoding
    conv0 = loaded.conv_layers[0]
    assert conv0.stride == cfg.conv[0].stride
    assert conv0.weights_pm1.shape == dep.conv_layers[0].weights_pm1.shape
    np.testing.assert_array_equal(np.asarray(loaded.run(x, VOTES)), want_nl)
    np.testing.assert_array_equal(
        np.asarray(loaded.run(x, EACH, keys=keys)), want_si
    )


def test_save_load_noiseless_and_calibrated_config(tmp_path):
    """noise=None round-trips as None; a noiseless-physics deployment
    keeps its NOISELESS model; non-default ensemble fields survive."""
    dep, sizes = _mlp_deployment("2048x64")
    dep.save(tmp_path / "plain")
    assert Deployment.load(tmp_path / "plain").noise is None

    nl, _ = _mlp_deployment("2048x64", noise=NOISELESS)
    nl.save(tmp_path / "nl")
    back = Deployment.load(tmp_path / "nl")
    assert back.noise == NOISELESS and back.noise is not None

    # a NON-default ens_cfg.noise field round-trips too (the pipeline
    # ignores it — physics come from Deployment.noise — but
    # load(save(d)).ens_cfg must equal d.ens_cfg field for field)
    sizes, bias = BANK_NETS["2048x64"], BANK_BIAS["2048x64"]
    folded = _random_folded(sizes, seed=1, bias_cells=bias)
    ec = ensemble.EnsembleConfig(bias_cells=bias, noise=SILICON)
    dep = deploy(folded, ens_cfg=ec, impl="xla", min_bucket=8)
    dep.save(tmp_path / "ecn")
    assert Deployment.load(tmp_path / "ecn").ens_cfg == ec


def test_load_rejects_non_deployment_dirs(tmp_path):
    with pytest.raises(FileNotFoundError, match="deployment.json"):
        Deployment.load(tmp_path / "missing")
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / "deployment.json").write_text('{"schema": "other/v9"}')
    with pytest.raises(ValueError, match="schema"):
        Deployment.load(bad)
    assert not is_deployment_dir(tmp_path / "missing")


# ---------------------------------------------------------------------------
# serving: register from a live Deployment and from a checkpoint path
# ---------------------------------------------------------------------------
def test_server_registers_deployment_and_checkpoint_path(tmp_path):
    dep, sizes = _mlp_deployment("2048x64", max_bucket=32)
    si, _ = _mlp_deployment("2048x64", noise=SILICON, max_bucket=32)
    si.save(tmp_path / "si")

    x = np.random.default_rng(3).choice(
        [-1.0, 1.0], (17, sizes[0])).astype(np.float32)
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(4), len(x)))
    want_nl = np.asarray(dep.run(x, VOTES))
    want_si = np.asarray(si.run(x, EACH, keys=keys))

    srv = PicBnnServer(BatchingPolicy(max_batch=8, max_wait_us=200.0))
    srv.register("live", dep)  # live Deployment (layer_sizes derived)
    srv.register("disk", str(tmp_path / "si"))  # checkpoint directory
    with srv:
        hs_nl = [srv.submit("live", x[i]) for i in range(len(x))]
        hs_si = [srv.submit("disk", x[i], key=keys[i])
                 for i in range(len(x))]
        got_nl = np.stack([h.result(timeout=60).votes for h in hs_nl])
        got_si = np.stack([h.result(timeout=60).votes for h in hs_si])
    np.testing.assert_array_equal(got_nl, want_nl)
    np.testing.assert_array_equal(got_si, want_si)
    st = srv.stats()
    # layer_sizes derived from the MLP deployment -> Table-II equivalent
    assert st.per_model["live"].silicon_inf_per_s > 0


def test_server_warmup_reports_spec_attribution():
    dep, _sizes = _mlp_deployment("2048x64", noise=SILICON, max_bucket=16)
    srv = PicBnnServer(BatchingPolicy(max_batch=16, max_wait_us=200.0))
    srv.register("m", dep, mc_samples=2)
    report = srv.warmup()
    spec = InferenceSpec(noise="per_request", mc_samples=2,
                         reduction="sum")
    assert set(report) == {"m"}
    assert set(report["m"]) == {(spec, 8), (spec, 16)}
    assert all(t > 0 for t in report["m"].values())

"""Unified search physics: single-source-of-truth noise semantics.

The regression surface of the silicon-mode refactor: every sigma of
`NoiseModel` must individually perturb every noisy path (the PR-1 "dead
noise gates" tested sigma_vref / sigma_tjitter but never applied them),
the noiseless limit of every path must be bit-exact, the pass-global vs
per-row draw structure must match the hardware (one MLSA reference / one
strobe per search; per-row mismatch), and the fused-noisy vote
distribution must agree with the faithful 33-search flow.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binarize, bnn, ensemble, physics
from repro.core.cam import CAMArray, query_with_bias
from repro.core.device_model import NOISELESS, SILICON, NoiseModel

ZERO = NoiseModel(sigma_hd=0.0, sigma_vref=0.0, sigma_tjitter=0.0,
                  temp_drift_hd=0.0)
SIGMAS = {
    "sigma_hd": 2.0,
    "sigma_vref": 0.05,
    "sigma_tjitter": 0.1,
    "temp_drift_hd": 3.0,
}


def _one_sigma(name):
    return dataclasses.replace(ZERO, **{name: SIGMAS[name]})


def _random_head(seed=0, n_classes=10, n_in=128):
    rng = np.random.default_rng(seed)
    layer = bnn.FoldedLayer(
        weights_pm1=rng.choice([-1, 1], (n_classes, n_in)).astype(np.int8),
        c=rng.integers(-30, 31, n_classes),
    )
    cfg = ensemble.EnsembleConfig()
    return ensemble.build_head(layer, cfg), cfg


# ---------------------------------------------------------------------------
# Sampler semantics
# ---------------------------------------------------------------------------
def test_noiseless_sample_is_base_schedule():
    head, _ = _random_head()
    phys = physics.SearchPhysics.for_head(head, NOISELESS)
    t = np.asarray(phys.sample(jax.random.PRNGKey(0), (4,), 10))
    base = np.asarray(head.thresholds, np.float32)
    assert t.shape == (33, 4, 10)
    np.testing.assert_array_equal(t, np.broadcast_to(
        base[:, None, None], t.shape))
    # key=None takes the same deterministic path
    np.testing.assert_array_equal(np.asarray(phys.sample(None, (4,), 10)), t)


def test_silicon_sample_mean_tracks_base():
    head, _ = _random_head()
    phys = physics.SearchPhysics.for_head(head, SILICON)
    t = np.asarray(phys.sample(jax.random.PRNGKey(0), (2000,), 10))
    base = np.asarray(head.thresholds, np.float32)
    # mean over the MC axis concentrates on the base schedule (the jitter
    # term 1/(1+eps) has a small positive bias ~sigma^2; tolerance covers)
    err = np.abs(t.mean(axis=(1, 2)) - base)
    assert err.max() < 2.5, err


def test_pass_global_vs_per_row_draw_structure():
    """vref/strobe draws are shared across rows of one search; sigma_hd
    is drawn per row — the hardware's noise topology."""
    head, _ = _random_head()
    key = jax.random.PRNGKey(1)
    for name in ("sigma_vref", "sigma_tjitter"):
        phys = physics.SearchPhysics.for_head(head, _one_sigma(name))
        t = np.asarray(phys.sample(key, (8,), 10))
        # within one (pass, batch) search, all rows see the same threshold
        assert np.ptp(t, axis=-1).max() < 1e-5, name
        # ... but the draws differ across searches
        assert t.std() > 0, name
    phys = physics.SearchPhysics.for_head(head, _one_sigma("sigma_hd"))
    t = np.asarray(phys.sample(key, (8,), 10))
    assert np.ptp(t, axis=-1).min() > 0  # per-row variation in every search


def test_temp_drift_is_deterministic_offset():
    head, _ = _random_head()
    phys = physics.SearchPhysics.for_head(head, _one_sigma("temp_drift_hd"))
    t = np.asarray(phys.sample(jax.random.PRNGKey(0), (4,), 10))
    base = np.asarray(head.thresholds, np.float32)[:, None, None]
    np.testing.assert_allclose(
        t, np.broadcast_to(base + SIGMAS["temp_drift_hd"], t.shape),
        rtol=1e-6,
    )


# ---------------------------------------------------------------------------
# Dead-gate regressions: each sigma individually perturbs every consumer
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(SIGMAS))
def test_each_sigma_perturbs_cam_search(name):
    rng = np.random.default_rng(3)
    cam = CAMArray.from_bits(rng.integers(0, 2, (64, 128)).astype(np.uint8))
    q = binarize.pack_bits(
        jnp.asarray(rng.integers(0, 2, (16, 128)).astype(np.uint8)))
    clean = np.asarray(cam.search(q, 60))
    noisy = np.asarray(
        cam.search(q, 60, noise=_one_sigma(name), key=jax.random.PRNGKey(0)))
    assert (clean != noisy).any(), name
    # and the noiseless model with a key stays bit-exact
    np.testing.assert_array_equal(
        clean, np.asarray(cam.search(q, 60, noise=ZERO,
                                     key=jax.random.PRNGKey(0))))


@pytest.mark.parametrize("name", sorted(SIGMAS))
def test_each_sigma_perturbs_votes_faithful(name):
    head, _ = _random_head(5)
    x = binarize.random_pm1(jax.random.PRNGKey(2), (16, 128))
    clean = np.asarray(ensemble.votes_faithful(head, x))
    noisy = np.asarray(ensemble.votes_faithful(
        head, x, noise=_one_sigma(name), key=jax.random.PRNGKey(0)))
    assert (clean != noisy).any(), name


@pytest.mark.parametrize("name", sorted(SIGMAS))
def test_each_sigma_perturbs_accuracy_sweep(name):
    head, cfg = _random_head(7)
    x = binarize.random_pm1(jax.random.PRNGKey(4), (64, 128))
    labels = np.asarray(ensemble.votes_fused(head, x)).argmax(-1)
    clean = ensemble.accuracy_sweep(head, x, labels, cfg)
    ncfg = dataclasses.replace(cfg, noise=_one_sigma(name))
    noisy = ensemble.accuracy_sweep(
        head, x, labels, ncfg, key=jax.random.PRNGKey(0))
    assert any(
        clean[p]["top1"] != noisy[p]["top1"] for p in clean
    ), name


def test_search_knobs_each_sigma_perturbs():
    rng = np.random.default_rng(9)
    cam = CAMArray.from_bits(rng.integers(0, 2, (32, 64)).astype(np.uint8))
    q = binarize.pack_bits(
        jnp.asarray(rng.integers(0, 2, (8, 64)).astype(np.uint8)))
    clean = np.asarray(cam.search_knobs(q, 0.95, 0.525, 1.1))
    for name in sorted(SIGMAS):
        noisy = np.asarray(cam.search_knobs(
            q, 0.95, 0.525, 1.1, noise=_one_sigma(name),
            key=jax.random.PRNGKey(1)))
        assert (clean != noisy).any(), name


# ---------------------------------------------------------------------------
# Fused-noisy vs faithful: same distribution (the LLN mechanism)
# ---------------------------------------------------------------------------
def test_fused_noisy_matches_faithful_distribution():
    """Per-class vote mean/std of the fused-noisy path agree with the
    33-sequential-search faithful flow under SILICON within Monte-Carlo
    tolerance (seeded, >= 1k trials each)."""
    head, _ = _random_head(11)
    x = binarize.random_pm1(jax.random.PRNGKey(6), (4, 128))
    phys = physics.SearchPhysics.for_head(head, SILICON)
    n = 1024

    def faithful(k):
        return ensemble.votes_faithful(head, x, key=k, physics=phys)

    def fused(k):
        return ensemble.votes_fused_noisy(head, x, key=k, physics=phys)

    kf = jax.random.split(jax.random.PRNGKey(100), n)
    kz = jax.random.split(jax.random.PRNGKey(200), n)
    vf = np.asarray(jax.jit(jax.vmap(faithful))(kf))  # [n, 4, C]
    vz = np.asarray(jax.jit(jax.vmap(fused))(kz))
    se = vf.std(0).max() / np.sqrt(n)
    assert np.abs(vf.mean(0) - vz.mean(0)).max() < max(6 * se, 0.5)
    assert np.abs(vf.std(0) - vz.std(0)).max() < 0.5
    # identical keys => identical draws: the two paths share ONE sampler
    np.testing.assert_array_equal(
        np.asarray(faithful(kf[0])), np.asarray(fused(kf[0])))


def test_votes_fused_noisy_noiseless_limit_bit_exact():
    head, _ = _random_head(13)
    x = binarize.random_pm1(jax.random.PRNGKey(8), (16, 128))
    np.testing.assert_array_equal(
        np.asarray(ensemble.votes_fused_noisy(
            head, x, key=jax.random.PRNGKey(0), noise=NOISELESS)),
        np.asarray(ensemble.votes_fused(head, x)),
    )


# ---------------------------------------------------------------------------
# Calibrated thresholds: knob_schedule round-trip through build_head
# ---------------------------------------------------------------------------
def test_calibrated_thresholds_roundtrip_build_head():
    rng = np.random.default_rng(17)
    layer = bnn.FoldedLayer(
        weights_pm1=rng.choice([-1, 1], (10, 128)).astype(np.int8),
        c=rng.integers(-30, 31, 10),
    )
    cfg = ensemble.EnsembleConfig(calibrated=True)
    head = ensemble.build_head(layer, cfg)
    sweep = np.asarray(cfg.thresholds, np.int64)
    center = (128 + cfg.bias_cells) // 2
    want = (center - sweep.max() // 2
            + physics.achieved_sweep(len(sweep), int(sweep.max())))
    assert head.thresholds.dtype == jnp.float32
    np.testing.assert_allclose(
        np.asarray(head.thresholds), want.astype(np.float32), rtol=1e-6)
    # achieved values stay close to the ideal sweep (Table-I calibration)
    ideal = ensemble.build_head(layer, ensemble.EnsembleConfig()).thresholds
    assert np.abs(np.asarray(head.thresholds)
                  - np.asarray(ideal, np.float32)).max() <= 3.0
    # and the head is consumable by every vote path unchanged
    x = binarize.random_pm1(jax.random.PRNGKey(3), (8, 128))
    np.testing.assert_array_equal(
        np.asarray(ensemble.votes_fused(head, x)),
        np.asarray(ensemble.votes_faithful(head, x)),
    )
    np.testing.assert_array_equal(
        np.asarray(ensemble.votes_kernel(head, x)),
        np.asarray(ensemble.votes_fused(head, x)),
    )


def test_vref_sensitivity_sign_and_magnitude():
    from repro.core.device_model import default_params, hd_threshold

    p = default_params()
    dm = float(physics.vref_sensitivity(p, 0.95, 0.525, 1.1))
    assert dm < 0  # raising V_ref always lowers the tolerance
    # matches a central finite difference of the behavioural model
    eps = 1e-4
    fd = (float(hd_threshold(p, 0.95 + eps, 0.525, 1.1))
          - float(hd_threshold(p, 0.95 - eps, 0.525, 1.1))) / (2 * eps)
    np.testing.assert_allclose(dm, fd, rtol=1e-3)


# ---------------------------------------------------------------------------
# Slow tier: the full Monte-Carlo robustness sweep (opt-in)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_full_noise_robustness_sweep():
    """Full-size benchmark sweep: fused-MC speedup >= 5x and the trained
    LLN claim (silicon within ~1 point at 33 passes).  Opt-in via
    --run-slow; the fast deterministic slice runs in scripts/smoke.sh."""
    from benchmarks import noise_robustness

    rows, record = noise_robustness.bench()
    assert record["speedup"]["speedup"] >= 5.0, record["speedup"]
    lln = noise_robustness.trained_lln()
    assert lln["delta_points"] <= 1.5, lln

"""Classification serving subsystem (serve/picbnn.py + serve/scheduler.py).

The correctness bar: serving is a SCHEDULING layer — it may coalesce,
pad, reorder, and fan out however it likes, but every served result must
be bit-exact equal to a direct CompiledPipeline call on the same input,
noiseless and seeded-silicon, across the macro's three logical bank
configurations.  Silicon determinism rides the per-request-key entry
points (`votes_each` / `votes_mc_each`), whose batch-composition
invariance is itself tested here.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import pipeline
from repro.core import bnn, ensemble
from repro.core.device_model import NOISELESS, SILICON
from repro.serve.picbnn import BatchingPolicy, PicBnnServer, QueueFullError
from repro.serve.scheduler import MicroBatcher, latency_summary

# Same bank-configuration nets as tests/test_pipeline.py: head rows land
# on each of the macro's logical row widths (256 / 128 / 64 bits).
BANK_NETS = {
    "512x256": (300, 192, 12),
    "1024x128": (784, 64, 10),
    "2048x64": (96, 32, 5),
}
BANK_BIAS = {"512x256": 64, "1024x128": 64, "2048x64": 32}


def _random_folded(sizes, seed, bias_cells):
    rng = np.random.default_rng(seed)
    layers = []
    for i in range(len(sizes) - 1):
        n_in, n_out = sizes[i], sizes[i + 1]
        c = bnn.parity_adjust_c(
            rng.integers(-bias_cells, bias_cells + 1, n_out), n_in, bias_cells
        )
        layers.append(bnn.FoldedLayer(
            weights_pm1=rng.choice([-1, 1], (n_out, n_in)).astype(np.int8),
            c=c,
        ))
    return layers


def _make_pipe(bank, noise=None, **kw):
    sizes, bias = BANK_NETS[bank], BANK_BIAS[bank]
    folded = _random_folded(sizes, seed=sum(map(ord, bank)), bias_cells=bias)
    return pipeline.compile_pipeline(
        folded, ensemble.EnsembleConfig(bias_cells=bias), impl="xla",
        min_bucket=8, noise=noise, **kw
    ), sizes


def _images(n, n_in, seed=1):
    rng = np.random.default_rng(seed)
    return rng.choice([-1.0, 1.0], (n, n_in)).astype(np.float32)


# ---------------------------------------------------------------------------
# per-request-key pipeline entries (the silicon serving contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bank", sorted(BANK_NETS))
def test_votes_each_batch_composition_invariant(bank):
    """votes_each row i depends only on (x_i, keys_i): any batch split —
    including single-request calls, which hit different bucket paddings —
    returns identical votes."""
    pipe, sizes = _make_pipe(bank, noise=SILICON)
    x = _images(21, sizes[0])
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(7), 21))
    full = np.asarray(pipe.votes_each(x, keys))
    split = np.concatenate([
        np.asarray(pipe.votes_each(x[:13], keys[:13])),
        np.asarray(pipe.votes_each(x[13:], keys[13:])),
    ])
    np.testing.assert_array_equal(full, split)
    for i in (0, 11, 20):
        np.testing.assert_array_equal(
            np.asarray(pipe.votes_each(x[i:i + 1], keys[i:i + 1]))[0],
            full[i],
        )
    # a real draw, not the noiseless staircase
    assert (full != np.asarray(pipe.votes(x))).any()


def test_votes_each_noiseless_limit_and_mc_identity():
    pipe, sizes = _make_pipe("1024x128", noise=NOISELESS)
    x = _images(9, sizes[0])
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(0), 9))
    np.testing.assert_array_equal(
        np.asarray(pipe.votes_each(x, keys)), np.asarray(pipe.votes(x))
    )
    si, _ = _make_pipe("1024x128", noise=SILICON)
    mc = np.asarray(si.votes_mc_each(x, keys, 4))  # [S, B, C]
    assert mc.shape[0] == 4
    for s in range(4):
        for i in (0, 8):
            ks = np.asarray(jax.random.split(jnp.asarray(keys[i]), 4))[s]
            np.testing.assert_array_equal(
                mc[s, i],
                np.asarray(si.votes_each(x[i:i + 1], ks[None]))[0],
            )


def test_votes_each_rejects_bad_keys_and_noiseless_pipe():
    pipe, sizes = _make_pipe("2048x64")  # no noise= at all
    x = _images(3, sizes[0])
    with pytest.raises(ValueError, match="noise="):
        pipe.votes_each(x, np.zeros((3, 2), np.uint32))
    si, _ = _make_pipe("2048x64", noise=SILICON)
    with pytest.raises(ValueError, match="keys"):
        si.votes_each(x, np.zeros((5, 2), np.uint32))  # wrong B


# ---------------------------------------------------------------------------
# warmup / bucket grid
# ---------------------------------------------------------------------------
def test_next_bucket_guards_and_grid():
    with pytest.raises(ValueError, match=">= 1"):
        pipeline.next_bucket(0, 8)
    with pytest.raises(ValueError, match=">= 1"):
        pipeline.next_bucket(-3, 8)
    with pytest.raises(ValueError, match="max_bucket"):
        pipeline.next_bucket(33, 8, max_bucket=32)
    assert pipeline.next_bucket(32, 8, max_bucket=32) == 32
    assert pipeline.bucket_grid(33, 8) == (8, 16, 32, 64)
    assert pipeline.bucket_grid(1, 8) == (8,)


def test_warmup_covers_bucket_grid():
    pipe, sizes = _make_pipe("2048x64", noise=SILICON, max_bucket=32)
    times = pipe.warmup(32, mc_samples=2)
    # per-(spec, bucket) attribution: every default spec at every bucket
    assert sorted({b for _spec, b in times}) == [8, 16, 32]
    assert {spec for spec, _b in times} == \
        set(pipe.default_warmup_specs(2))
    assert all(t > 0 for t in times.values())
    # warmed entries run without error at every bucket and ragged sizes
    for b in (1, 8, 9, 32):
        x = _images(b, sizes[0])
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(b), b))
        assert np.asarray(pipe.votes_each(x, keys)).shape == (b, sizes[-1])
    with pytest.raises(ValueError, match="max_bucket"):
        pipe.votes(_images(33, sizes[0]))


# ---------------------------------------------------------------------------
# MicroBatcher policy logic (fake clock — no sleeping)
# ---------------------------------------------------------------------------
def _lots(spans):
    """Flatten dispatched spans to (lot, lo, hi) triples for asserts."""
    return [(s.lot, s.lo, s.hi) for s in spans]


def test_microbatcher_full_batch_dispatches_immediately():
    clock = [0.0]
    mb = MicroBatcher(BatchingPolicy(max_batch=4, max_wait_us=1e6),
                      clock=lambda: clock[0])
    for i in range(9):
        mb.put("m", i)
    lane, spans = mb.next_batch(timeout=0)
    assert lane == "m"
    assert _lots(spans) == [(0, 0, 1), (1, 0, 1), (2, 0, 1), (3, 0, 1)]
    lane, spans = mb.next_batch(timeout=0)
    assert [s.lot for s in spans] == [4, 5, 6, 7]
    # 1 leftover: not full, deadline not reached -> nothing due
    assert mb.next_batch(timeout=0) is None
    assert mb.depth == 1


def test_microbatcher_splits_lots_and_keeps_deadline():
    """A burst larger than max_batch dispatches as consecutive spans of
    one lot; the remainder keeps the ORIGINAL enqueue time (its deadline
    clock must not reset when the front is carved off)."""
    clock = [0.0]
    mb = MicroBatcher(BatchingPolicy(max_batch=4, max_wait_us=2000.0),
                      clock=lambda: clock[0])
    mb.put("m", "burst", size=10)
    lane, spans = mb.next_batch(timeout=0)  # full batch available
    assert _lots(spans) == [("burst", 0, 4)]
    lane, spans = mb.next_batch(timeout=0)
    assert _lots(spans) == [("burst", 4, 8)]
    assert mb.next_batch(timeout=0) is None  # 2 left: partial, not due
    clock[0] = 0.0021  # original enqueue time + 2 ms passed
    lane, spans = mb.next_batch(timeout=0)
    assert _lots(spans) == [("burst", 8, 10)]
    assert mb.depth == 0


def test_microbatcher_deadline_dispatches_partial():
    clock = [0.0]
    mb = MicroBatcher(BatchingPolicy(max_batch=100, max_wait_us=2000.0),
                      clock=lambda: clock[0])
    mb.put("m", "a")
    clock[0] = 0.001  # 1 ms < 2 ms deadline
    mb.put("m", "b")
    assert mb.next_batch(timeout=0) is None
    clock[0] = 0.0021  # oldest request now past its 2 ms deadline
    lane, spans = mb.next_batch(timeout=0)
    assert [s.lot for s in spans] == ["a", "b"]


def test_microbatcher_lanes_never_mix_and_oldest_first():
    clock = [0.0]
    mb = MicroBatcher(BatchingPolicy(max_batch=10, max_wait_us=1000.0),
                      clock=lambda: clock[0])
    mb.put("a", 1)
    clock[0] = 1e-4
    mb.put("b", 2)
    mb.put("a", 3)
    clock[0] = 0.01  # both lanes past deadline; lane "a" is older
    lane, spans = mb.next_batch(timeout=0)
    assert lane == "a" and [s.lot for s in spans] == [1, 3]
    lane, spans = mb.next_batch(timeout=0)
    assert lane == "b" and [s.lot for s in spans] == [2]


def test_microbatcher_full_lane_beats_older_partial():
    clock = [0.0]
    mb = MicroBatcher(BatchingPolicy(max_batch=2, max_wait_us=1e9),
                      clock=lambda: clock[0])
    mb.put("old", 0)
    clock[0] = 1.0  # "old" is older but nowhere near its deadline
    mb.put("full", 1)
    mb.put("full", 2)
    lane, _ = mb.next_batch(timeout=0)
    assert lane == "full"  # dispatching it costs no extra waiting


def test_microbatcher_expired_partial_beats_flooded_full_lane():
    """The bounded-delay contract: a perpetually-full sibling lane must
    not starve a partial batch whose max_wait deadline has expired."""
    clock = [0.0]
    mb = MicroBatcher(BatchingPolicy(max_batch=2, max_wait_us=1000.0),
                      clock=lambda: clock[0])
    mb.put("slow", "victim")
    clock[0] = 0.002  # victim is now past its 1 ms deadline
    mb.put("flood", "burst", size=50)  # always >= max_batch
    lane, spans = mb.next_batch(timeout=0)
    assert lane == "slow" and [s.lot for s in spans] == ["victim"]
    lane, _ = mb.next_batch(timeout=0)  # then the flood drains
    assert lane == "flood"


def test_microbatcher_queue_bound_and_drain_on_close():
    mb = MicroBatcher(BatchingPolicy(max_batch=8, max_wait_us=1e6,
                                     max_queue=3))
    for i in range(3):
        mb.put("m", i)
    with pytest.raises(QueueFullError):
        mb.put("m", 99, block=False)
    with pytest.raises(QueueFullError):  # lot admission is all-or-nothing
        mb.put("m", "burst", size=2, block=False)
    with pytest.raises(QueueFullError):  # a lot that can NEVER fit must
        mb.put("m", "huge", size=4)  # reject even when block=True
    assert mb.high_water == 3
    mb.close()
    with pytest.raises(RuntimeError):
        mb.put("m", 100)
    lane, spans = mb.next_batch()  # close drains partials immediately
    assert [s.lot for s in spans] == [0, 1, 2]
    assert mb.next_batch() is None  # closed + empty


def test_latency_summary_percentiles():
    s = latency_summary(list(range(1, 101)))
    assert (s.n, s.p50_ms, s.max_ms) == (100, 50.5, 100.0)
    assert s.p99_ms > s.p95_ms > s.p50_ms
    assert latency_summary([]).n == 0


# ---------------------------------------------------------------------------
# served results are bit-exact vs direct pipeline calls
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bank", sorted(BANK_NETS))
def test_served_noiseless_bit_exact(bank):
    pipe, sizes = _make_pipe(bank, max_bucket=64)
    x = _images(43, sizes[0], seed=3)
    want_votes = np.asarray(pipe.votes(x))
    want_pred = want_votes.argmax(-1)
    srv = PicBnnServer(BatchingPolicy(max_batch=16, max_wait_us=200.0))
    srv.register(bank, pipe, layer_sizes=sizes)
    with srv:
        handles = [srv.submit(bank, x[i]) for i in range(len(x))]
        results = [h.result(timeout=60) for h in handles]
    for i, r in enumerate(results):
        assert r.pred == want_pred[i]
        np.testing.assert_array_equal(r.votes, want_votes[i])
        assert r.latency_ms >= r.service_ms >= 0
        assert r.queue_ms >= 0 and 1 <= r.batch_size <= 16
        assert r.bucket in pipe.buckets_for(16)
    st = srv.stats()
    assert st.n_requests == len(x)
    assert st.per_model[bank].silicon_inf_per_s > 0
    assert 0 < st.mean_occupancy <= 1.0


def test_submit_many_burst_bit_exact_and_split_across_batches():
    """A burst bigger than max_batch splits across micro-batches but
    returns one coherent, bit-exact result set (noiseless + silicon)."""
    pipe, sizes = _make_pipe("1024x128", max_bucket=64)
    si, _ = _make_pipe("1024x128", noise=SILICON, max_bucket=64)
    x = _images(41, sizes[0], seed=9)
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(5), len(x)))
    srv = PicBnnServer(BatchingPolicy(max_batch=16, max_wait_us=300.0))
    srv.register("n", pipe)
    srv.register("s", si)
    with srv:
        gn = srv.submit_many("n", x)
        gs = srv.submit_many("s", x, keys=keys)
        preds = gn.wait_all(timeout=60)
        votes = gs.votes_all(timeout=60)
        res = gn.results(timeout=60)
    np.testing.assert_array_equal(preds, np.asarray(pipe.predict(x)))
    np.testing.assert_array_equal(votes,
                                  np.asarray(si.votes_each(x, keys)))
    assert len(gn) == len(res) == 41
    # burst of 41 with max_batch 16 -> split across >= 3 micro-batches
    assert len({id(r) for r in res}) == 41
    assert len(gn._slab.spans) >= 3
    uids = [r.uid for r in res]
    assert uids == list(range(uids[0], uids[0] + 41))


@pytest.mark.parametrize("bank", sorted(BANK_NETS))
def test_served_silicon_seeded_bit_exact_any_batching(bank):
    """Per-request keys make silicon serving deterministic: two servers
    with very different coalescing policies return identical, directly-
    reproducible votes."""
    pipe, sizes = _make_pipe(bank, noise=SILICON, max_bucket=64)
    x = _images(29, sizes[0], seed=4)
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(11), len(x)))
    want = np.asarray(pipe.votes_each(x, keys))
    for pol in (BatchingPolicy(max_batch=4, max_wait_us=100.0),
                BatchingPolicy(max_batch=32, max_wait_us=5000.0)):
        srv = PicBnnServer(pol)
        srv.register("si", pipe)
        with srv:
            hs = [srv.submit("si", x[i], key=keys[i])
                  for i in range(len(x))]
            got = np.stack([h.result(timeout=60).votes for h in hs])
        np.testing.assert_array_equal(got, want)


def test_served_mc_model_matches_votes_mc_each():
    pipe, sizes = _make_pipe("2048x64", noise=SILICON, max_bucket=32)
    x = _images(11, sizes[0], seed=5)
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(2), len(x)))
    want = np.asarray(pipe.votes_mc_each(x, keys, 5)).sum(0)
    srv = PicBnnServer(BatchingPolicy(max_batch=8, max_wait_us=200.0))
    srv.register("mc", pipe, mc_samples=5)
    with srv:
        hs = [srv.submit("mc", x[i], key=keys[i]) for i in range(len(x))]
        res = [h.result(timeout=60) for h in hs]
    np.testing.assert_array_equal(np.stack([r.votes for r in res]), want)
    np.testing.assert_array_equal([r.pred for r in res], want.argmax(-1))


def test_mixed_model_traffic_never_mixes_batches():
    p1, s1 = _make_pipe("1024x128", max_bucket=32)
    p2, s2 = _make_pipe("2048x64", noise=SILICON, max_bucket=32)
    x1 = _images(17, s1[0], seed=6)
    x2 = _images(13, s2[0], seed=7)
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(3), len(x2)))
    srv = PicBnnServer(BatchingPolicy(max_batch=8, max_wait_us=300.0))
    srv.register("noiseless", p1, layer_sizes=s1)
    srv.register("silicon", p2, layer_sizes=s2)
    with srv:
        hs = []
        for i in range(max(len(x1), len(x2))):  # interleaved arrival
            if i < len(x1):
                hs.append(("noiseless", i, srv.submit("noiseless", x1[i])))
            if i < len(x2):
                hs.append(("silicon", i,
                           srv.submit("silicon", x2[i], key=keys[i])))
        res = [(m, i, h.result(timeout=60)) for (m, i, h) in hs]
    want1 = np.asarray(p1.votes(x1))
    want2 = np.asarray(p2.votes_each(x2, keys))
    for m, i, r in res:
        assert r.model_id == m  # a batch serves exactly one model
        np.testing.assert_array_equal(
            r.votes, want1[i] if m == "noiseless" else want2[i]
        )
    st = srv.stats()
    assert st.per_model["noiseless"].n_requests == len(x1)
    assert st.per_model["silicon"].n_requests == len(x2)


def test_engine_submit_validation():
    pipe, sizes = _make_pipe("2048x64", max_bucket=32)
    si, _ = _make_pipe("2048x64", noise=SILICON, max_bucket=32)
    srv = PicBnnServer(BatchingPolicy(max_batch=8, max_wait_us=100.0))
    srv.register("n", pipe)
    srv.register("s", si)
    with pytest.raises(ValueError, match="mc_samples"):
        srv.register("bad", pipe, mc_samples=3)  # noiseless pipe
    with pytest.raises(ValueError, match="already registered"):
        srv.register("n", pipe)
    img = _images(1, sizes[0])[0]
    with srv:
        with pytest.raises(KeyError, match="unknown model"):
            srv.submit("nope", img)
        with pytest.raises(ValueError, match="PRNG key"):
            srv.submit("s", img)  # silicon without key
        with pytest.raises(ValueError, match="noiseless"):
            srv.submit("n", img, key=np.zeros(2, np.uint32))
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit("n", img)
    # stats() on a server that served nothing must not blow up
    empty = PicBnnServer(BatchingPolicy())
    assert empty.stats().n_requests == 0
    # a max_batch whose BUCKET exceeds the pipeline cap is caught at
    # start(), not on the first full dispatch (24 -> bucket 32 <= 32 ok,
    # 33 -> bucket 64 > 32 rejected even though 33 < ... is non-pow2)
    bad = PicBnnServer(BatchingPolicy(max_batch=33, max_wait_us=100.0))
    bad.register("n", pipe)  # pipe has max_bucket=32
    with pytest.raises(ValueError, match="bucket"):
        bad.start()
    from repro.serve import GroupHandle  # lazy public surface resolves
    assert GroupHandle is not None


def test_engine_queue_full_and_drain_on_close():
    pipe, sizes = _make_pipe("2048x64", max_bucket=32)
    x = _images(6, sizes[0], seed=8)
    want = np.asarray(pipe.votes(x)).argmax(-1)
    # deadline far away + batch bigger than the stream: the batcher holds
    # everything, so admission (max_queue=4) fills deterministically
    srv = PicBnnServer(BatchingPolicy(max_batch=32, max_wait_us=30e6,
                                      max_queue=4))
    srv.register("m", pipe)
    srv.start()
    hs = [srv.submit("m", x[i]) for i in range(4)]
    with pytest.raises(QueueFullError):
        srv.submit("m", x[4], block=False)
    with pytest.raises(QueueFullError):
        srv.submit("m", x[4], timeout=0.01)
    srv.close()  # close() flushes the held partial batch
    got = [h.result(timeout=30).pred for h in hs]
    np.testing.assert_array_equal(got, want[:4])


def test_lm_engine_per_request_timing():
    """serve/engine.py Results carry per-request queue/service times in
    the shared metrics vocabulary (not just batch-level phase timings)."""
    from repro import configs
    from repro.models import model as M
    from repro.serve.engine import Engine, EngineConfig, Request

    cfg = configs.get_config("llama3.2-1b+smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(max_batch=2, eos_id=-1))
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(1, 100, 6).astype(np.int32),
                max_new_tokens=2 if i == 0 else 5)
        for i in range(3)
    ]
    out = eng.generate(reqs)
    for r in out:
        assert r.service_ms > 0 and r.queue_ms >= 0
        assert r.latency_ms == pytest.approx(r.queue_ms + r.service_ms)
    # same batch, fewer tokens -> request 0 finishes no later than 1
    assert out[0].service_ms <= out[1].service_ms
    # batch 2 (request uid=2) queues behind batch 1
    assert out[2].queue_ms >= out[0].queue_ms


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 "
        + os.environ.get("XLA_FLAGS", "")
    )
    os.environ["JAX_PLATFORMS"] = "cpu"
    import jax, numpy as np, jax.numpy as jnp
    from repro import pipeline
    from repro.core import bnn, ensemble
    from repro.serve.picbnn import PicBnnServer, BatchingPolicy

    assert jax.device_count() == 4
    rng = np.random.default_rng(0)
    sizes, bias = (96, 32, 5), 32
    layers = []
    for i in range(len(sizes) - 1):
        n_in, n_out = sizes[i], sizes[i + 1]
        c = bnn.parity_adjust_c(
            rng.integers(-bias, bias + 1, n_out), n_in, bias)
        layers.append(bnn.FoldedLayer(
            weights_pm1=rng.choice([-1, 1], (n_out, n_in)).astype(np.int8),
            c=c))
    pipe = pipeline.compile_pipeline(
        layers, ensemble.EnsembleConfig(bias_cells=bias), impl="xla",
        min_bucket=8, max_bucket=64)
    x = rng.choice([-1.0, 1.0], (40, sizes[0])).astype(np.float32)
    want = np.asarray(pipe.predict(x))
    for fanout in ("round_robin", "spmd"):
        srv = PicBnnServer(
            BatchingPolicy(max_batch=8, max_wait_us=200.0), fanout=fanout)
        srv.register("m", pipe)
        srv.warmup()  # covers device- and sharding-targeted warmup
        with srv:
            hs = [srv.submit("m", x[i]) for i in range(len(x))]
            res = [h.result(timeout=60) for h in hs]
        np.testing.assert_array_equal([r.pred for r in res], want)
        if fanout == "round_robin":
            # the ring actually fanned batches out across devices
            assert len({r.device for r in res}) > 1, \\
                sorted({r.device for r in res})
    print("MULTIDEV-OK")
""")


def test_multi_device_fanout_subprocess():
    """Data-parallel fan-out on a forced 4-device host platform: both
    round-robin and SPMD fan-out serve bit-exact predictions, and the
    round-robin ring really spreads batches across devices.  Runs in a
    subprocess because device count is fixed at jax init."""
    src = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": src},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MULTIDEV-OK" in proc.stdout

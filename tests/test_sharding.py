"""Sharding rules: spec construction, sanitization, mesh resolution."""

import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip when hypothesis is absent
    from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.models import model as M
from repro.sharding import SERVE_RULES, TRAIN_RULES
from repro.sharding.rules import sanitize_spec

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_dedups_physical_axes():
    # two logical axes mapping to 'model': only the first keeps it
    spec = TRAIN_RULES.spec("heads", "mlp")
    assert spec == P("model", None)


def test_spec_tuple_axes():
    spec = TRAIN_RULES.spec("batch", "seq")
    assert spec == P(("pod", "data"), None)


def test_resolve_drops_missing_axes(mesh11):
    r = TRAIN_RULES.resolve(mesh11)
    assert r.spec("batch") == P(("data",))
    mesh3 = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    r3 = TRAIN_RULES.resolve(mesh3)
    assert r3.spec("batch") == P(("pod", "data"))


@given(
    st.lists(st.integers(1, 48), min_size=1, max_size=4),
    st.integers(0, 3),
)
def test_sanitize_spec_always_valid(dims, which):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # build a spec naming axes on every dim
    axes = ["data", "model", None, ("data", "model")]
    spec = P(*[axes[(which + i) % 4] for i in range(len(dims))])
    out = sanitize_spec(spec, tuple(dims), mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for d, ax in zip(dims, tuple(out)):
        if ax is None:
            continue
        f = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            f *= sizes[a]
        assert d % f == 0


def test_sanitize_drops_indivisible():
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))

    # 24 heads on a 16-way axis -> replicated
    out = sanitize_spec(P(None, "model"), (64, 24), FakeMesh())
    assert out == P(None, None)
    # 32 heads divisible -> kept
    out = sanitize_spec(P(None, "model"), (64, 32), FakeMesh())
    assert out == P(None, "model")


@pytest.mark.parametrize("arch", configs.list_archs())
def test_param_pspecs_structure_matches_params(arch):
    cfg = configs.get_config(arch + "+smoke")
    params = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0))
    )
    pspecs = M.param_pspecs(cfg, TRAIN_RULES)
    # identical treedefs => every param leaf has a sharding rule
    t1 = jax.tree_util.tree_structure(params)
    t2 = jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda x: 0, pspecs,
                               is_leaf=lambda x: isinstance(x, P))
    )
    assert t1 == t2, f"{arch}: param/spec tree mismatch"


@pytest.mark.parametrize("arch", ["llama3.2-1b", "jamba-v0.1-52b"])
def test_cache_pspecs_structure(arch):
    cfg = configs.get_config(arch + "+smoke")
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 2, 32))
    cspecs = M.cache_pspecs(cfg, SERVE_RULES)
    t1 = jax.tree_util.tree_structure(cache)
    t2 = jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda x: 0, cspecs,
                               is_leaf=lambda x: isinstance(x, P))
    )
    assert t1 == t2


def test_train_rules_fsdp_shards_params():
    spec = TRAIN_RULES.spec("p_attn_d", "p_attn_heads", None)
    assert spec == P("data", "model", None)


def test_serve_rules_2d_weight_sharding():
    spec = SERVE_RULES.spec("p_mlp_d", "p_mlp_f")
    assert spec == P("data", "model")
    # experts sharded over the data axis in serving
    spec = SERVE_RULES.spec("p_expert", "p_mlp_d", "p_mlp_f")
    assert spec == P("data", None, "model")

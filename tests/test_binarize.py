"""Property tests for the binarization primitives (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip when hypothesis is absent
    from _hypothesis_compat import given, settings, st

from repro.core import binarize as B

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


bits_arrays = st.integers(1, 200).flatmap(
    lambda k: st.integers(1, 8).map(lambda n: (n, k))
)


@given(bits_arrays, st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(shape, seed):
    n, k = shape
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (n, k)).astype(np.uint8)
    packed = B.pack_bits(jnp.asarray(bits))
    assert packed.shape == (n, B.packed_width(k))
    un = B.unpack_bits(packed, k)
    np.testing.assert_array_equal(np.asarray(un), bits)


@given(bits_arrays, st.integers(0, 2**31 - 1))
def test_hamming_packed_equals_dense(shape, seed):
    n, k = shape
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2, (n, k)).astype(np.uint8)
    b = rng.integers(0, 2, (n, k)).astype(np.uint8)
    hd_dense = (a != b).sum(-1)
    hd_packed = B.hamming_packed(B.pack_bits(jnp.asarray(a)),
                                 B.pack_bits(jnp.asarray(b)))
    np.testing.assert_array_equal(np.asarray(hd_packed), hd_dense)


@given(st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_dot_from_hd_identity(k, seed):
    """<a, b> in +-1 equals n - 2*HD for every pair."""
    rng = np.random.default_rng(seed)
    a = rng.choice([-1.0, 1.0], (4, k))
    b = rng.choice([-1.0, 1.0], (3, k))
    hd = B.hamming_pm1(jnp.asarray(a)[:, None, :], jnp.asarray(b)[None, :, :])
    dot = a @ b.T
    np.testing.assert_array_equal(
        np.asarray(B.dot_from_hd(hd, k)), dot.astype(np.int64)
    )


def test_sign_ste_forward_and_grad():
    x = jnp.array([-2.0, -0.5, 0.0, 0.7, 1.5])
    y = B.sign_ste(x)
    np.testing.assert_array_equal(np.asarray(y), [-1, -1, 1, 1, 1])
    g = jax.grad(lambda x: B.sign_ste(x).sum())(x)
    # clipped STE: gradient passes iff |x| <= 1
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 1.0, 1.0, 0.0])


def test_np_pack_matches_jnp():
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, (5, 77)).astype(np.uint8)
    np.testing.assert_array_equal(
        B.np_pack_bits(bits), np.asarray(B.pack_bits(jnp.asarray(bits)))
    )


@given(st.integers(1, 100))
def test_packed_width(k):
    assert B.packed_width(k) == (k + 31) // 32


def test_thermometer_roundtrip_and_monotone():
    """Encode/decode round-trip within quantization; code is monotone."""
    x = np.linspace(0.0, 1.0, 33, dtype=np.float32)
    for width in (1, 3, 8, 16):
        bits = np.asarray(B.thermometer_bits(jnp.asarray(x), width))
        assert bits.shape == (33, width) and set(np.unique(bits)) <= {0, 1}
        # thermometer property: all ones then all zeros along the width
        assert (np.diff(bits.astype(np.int8), axis=-1) <= 0).all()
        dec = np.asarray(B.thermometer_decode(jnp.asarray(bits)))
        # worst-case round-trip error is half a level
        assert np.abs(dec - x).max() <= 0.5 / (width + 1) + 1e-6
        # fill level is monotone in intensity
        fills = bits.sum(-1)
        assert (np.diff(fills) >= 0).all()


def test_thermometer_edge_cases():
    """All-zero image -> all-zero bits; width-1 == plain 0.5 threshold."""
    zero = jnp.zeros((4, 7))
    assert not np.asarray(B.thermometer_bits(zero, 8)).any()
    x = jnp.asarray([0.0, 0.49, 0.5, 1.0])
    np.testing.assert_array_equal(
        np.asarray(B.thermometer_bits(x, 1))[:, 0], [0, 0, 1, 1]
    )
    with pytest.raises(ValueError):
        B.thermometer_bits(x, 0)


def test_thermometer_hamming_faithful():
    """HD between thermometer codes == quantized intensity gap — the
    property that makes the encoding the right input layer for a
    Hamming-tolerant CAM search (DESIGN.md §10)."""
    width = 10
    x = jnp.asarray(np.linspace(0, 1, 12, dtype=np.float32))
    bits = B.thermometer_bits(x, width)
    fills = np.asarray(bits).sum(-1).astype(np.int64)
    hd = np.asarray(
        B.hamming_packed(
            B.pack_bits(bits)[:, None, :], B.pack_bits(bits)[None, :, :]
        )
    )
    np.testing.assert_array_equal(hd, np.abs(fills[:, None] - fills[None, :]))


def test_bitplane_roundtrip():
    """Exact round-trip on the 2^width-level grid; LSB-first planes."""
    for width in (1, 4, 8):
        levels = (1 << width) - 1
        x = jnp.asarray(np.arange(levels + 1, dtype=np.float32) / levels)
        bits = B.bitplane_bits(x, width)
        np.testing.assert_allclose(
            np.asarray(B.bitplane_decode(bits)), np.asarray(x), atol=1e-6
        )
        # plane t of the quantized value q is (q >> t) & 1
        q = np.arange(levels + 1)
        np.testing.assert_array_equal(
            np.asarray(bits), (q[:, None] >> np.arange(width)) & 1
        )
    assert not np.asarray(B.bitplane_bits(jnp.zeros((3, 2)), 5)).any()


def test_input_encoding_dispatch_and_validation():
    enc = B.InputEncoding("thermometer", 4)
    x = jnp.asarray([[0.0, 0.3, 0.9]])
    np.testing.assert_array_equal(
        np.asarray(enc.encode_bits(x)),
        np.asarray(B.thermometer_bits(x, 4)),
    )
    np.testing.assert_array_equal(
        np.asarray(enc.encode_pm1(x)),
        2.0 * np.asarray(enc.encode_bits(x)) - 1.0,
    )
    sign = B.InputEncoding("sign", 1)
    np.testing.assert_array_equal(
        np.asarray(sign.encode_bits(x))[..., 0], [[0, 0, 1]]
    )
    with pytest.raises(ValueError):
        B.InputEncoding("sign", 2)
    with pytest.raises(ValueError):
        B.InputEncoding("nope", 4)
    with pytest.raises(ValueError):
        B.InputEncoding("bitplane", 0)


def test_binary_matvec_packed():
    rng = np.random.default_rng(1)
    w = rng.choice([-1.0, 1.0], (10, 96))
    x = rng.choice([-1.0, 1.0], (4, 96))
    y = B.binary_matvec_packed(
        B.pack_bits(jnp.asarray((w > 0).astype(np.uint8))),
        B.pack_bits(jnp.asarray((x > 0).astype(np.uint8))),
        96,
    )
    np.testing.assert_array_equal(np.asarray(y), (x @ w.T).astype(np.int64))

"""Property tests for the binarization primitives (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip when hypothesis is absent
    from _hypothesis_compat import given, settings, st

from repro.core import binarize as B

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


bits_arrays = st.integers(1, 200).flatmap(
    lambda k: st.integers(1, 8).map(lambda n: (n, k))
)


@given(bits_arrays, st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(shape, seed):
    n, k = shape
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (n, k)).astype(np.uint8)
    packed = B.pack_bits(jnp.asarray(bits))
    assert packed.shape == (n, B.packed_width(k))
    un = B.unpack_bits(packed, k)
    np.testing.assert_array_equal(np.asarray(un), bits)


@given(bits_arrays, st.integers(0, 2**31 - 1))
def test_hamming_packed_equals_dense(shape, seed):
    n, k = shape
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2, (n, k)).astype(np.uint8)
    b = rng.integers(0, 2, (n, k)).astype(np.uint8)
    hd_dense = (a != b).sum(-1)
    hd_packed = B.hamming_packed(B.pack_bits(jnp.asarray(a)),
                                 B.pack_bits(jnp.asarray(b)))
    np.testing.assert_array_equal(np.asarray(hd_packed), hd_dense)


@given(st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_dot_from_hd_identity(k, seed):
    """<a, b> in +-1 equals n - 2*HD for every pair."""
    rng = np.random.default_rng(seed)
    a = rng.choice([-1.0, 1.0], (4, k))
    b = rng.choice([-1.0, 1.0], (3, k))
    hd = B.hamming_pm1(jnp.asarray(a)[:, None, :], jnp.asarray(b)[None, :, :])
    dot = a @ b.T
    np.testing.assert_array_equal(
        np.asarray(B.dot_from_hd(hd, k)), dot.astype(np.int64)
    )


def test_sign_ste_forward_and_grad():
    x = jnp.array([-2.0, -0.5, 0.0, 0.7, 1.5])
    y = B.sign_ste(x)
    np.testing.assert_array_equal(np.asarray(y), [-1, -1, 1, 1, 1])
    g = jax.grad(lambda x: B.sign_ste(x).sum())(x)
    # clipped STE: gradient passes iff |x| <= 1
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 1.0, 1.0, 0.0])


def test_np_pack_matches_jnp():
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, (5, 77)).astype(np.uint8)
    np.testing.assert_array_equal(
        B.np_pack_bits(bits), np.asarray(B.pack_bits(jnp.asarray(bits)))
    )


@given(st.integers(1, 100))
def test_packed_width(k):
    assert B.packed_width(k) == (k + 31) // 32


def test_binary_matvec_packed():
    rng = np.random.default_rng(1)
    w = rng.choice([-1.0, 1.0], (10, 96))
    x = rng.choice([-1.0, 1.0], (4, 96))
    y = B.binary_matvec_packed(
        B.pack_bits(jnp.asarray((w > 0).astype(np.uint8))),
        B.pack_bits(jnp.asarray((x > 0).astype(np.uint8))),
        96,
    )
    np.testing.assert_array_equal(np.asarray(y), (x @ w.T).astype(np.int64))

"""The trip-count-aware HLO walker vs ground truth programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import (
    analyze_hlo_text,
    cost_analysis_dict,
    parse_hlo,
)


def _compiled(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_trip_count_multiplication():
    """walker_flops(scan of L matmuls) ~ L * flops(one matmul)."""
    n = 128

    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=9)
        return y

    c = _compiled(f, jax.ShapeDtypeStruct((n, n), jnp.float32))
    t = analyze_hlo_text(c.as_text())
    dot_flops = 2 * n**3
    assert 9 * dot_flops <= t.flops <= 9 * dot_flops * 1.2
    # raw cost_analysis counts the body once — the reason the walker exists
    raw = cost_analysis_dict(c)["flops"]
    assert raw < t.flops / 4


def test_unrolled_matches_walker():
    n = 64

    def f_scan(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=6)
        return y

    def f_unroll(x):
        for _ in range(6):
            x = x @ x
        return x

    sds = jax.ShapeDtypeStruct((n, n), jnp.float32)
    t_scan = analyze_hlo_text(_compiled(f_scan, sds).as_text())
    raw_unroll = cost_analysis_dict(_compiled(f_unroll, sds))["flops"]
    assert abs(t_scan.flops - raw_unroll) / raw_unroll < 0.2


def test_nested_scan_trips_multiply():
    n = 32

    def f(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=4)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    c = _compiled(f, jax.ShapeDtypeStruct((n, n), jnp.float32))
    t = analyze_hlo_text(c.as_text())
    assert t.flops >= 12 * 2 * n**3  # 3 * 4 body executions


def test_collective_detection_multidevice():
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    # single device: no collectives expected
    def f(x):
        return x @ x

    c = _compiled(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    t = analyze_hlo_text(c.as_text())
    assert t.collective_count == 0
    assert t.collective_wire_bytes == 0.0


def test_parse_hlo_computations():
    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c * 2, None), x, None, length=5)
        return y.sum()

    txt = _compiled(f, jax.ShapeDtypeStruct((8,), jnp.float32)).as_text()
    comps = parse_hlo(txt)
    assert any("region" in n or "body" in n for n in comps)
    entries = [n for n in comps if "main" in n]
    assert entries


def test_dryrun_results_have_sane_ratios():
    """Cross-check the recorded sweep: walker flops >= raw cost_analysis
    flops for every scanned model (trip counts only add)."""
    import json
    from pathlib import Path

    d = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not d.exists():
        pytest.skip("no dry-run results yet")
    n = 0
    for p in d.glob("*__pod.json"):
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok":
            continue
        raw = rec["cost_analysis_raw"]["flops"]
        walker = rec["hlo_walker"]["device_flops"]
        if raw and raw > 0:
            assert walker >= raw * 0.5, p.name
            n += 1
    assert n >= 10
